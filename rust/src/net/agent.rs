//! The standalone slave event loop.
//!
//! A [`SlaveAgent`] owns the per-server [`DormSlave`] book and a
//! [`ControlPlane`] transport to the master.  Each beat it ships its
//! [`SlaveReport`] ([`Request::Heartbeat`]) and applies the master's
//! reconciliation [`Directive`]s to the local book — so the remote book
//! converges on the master's desired state even across lost acks, agent
//! restarts, or a master that re-solved while the packet was in flight.
//! Directive outcomes are *batched*: each beat carries the whole vector
//! of [`DirectiveAck`]s accumulated since the last successful heartbeat
//! (proto v1.2), so acknowledging N directives costs zero extra round
//! trips instead of N.  If the master says the server is dead (leases
//! expired while the link was down), the agent re-registers with
//! [`Request::RecoverServer`] and rejoins empty, exactly like a repaired
//! machine.

use std::time::Duration;

use anyhow::{bail, Result};

use crate::net::ControlPlane;
use crate::proto::{AckKind, Directive, DirectiveAck, Request, Response};
use crate::slave::DormSlave;

/// What one heartbeat round did.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HeartbeatOutcome {
    /// The master's lease verdict for this server.
    pub alive: bool,
    /// Directives received (0 = the local book is converged).
    pub directives: usize,
    /// Directives that applied cleanly to the local book.
    pub applied: usize,
    /// The answering master's epoch was *older* than one this agent has
    /// already obeyed: it is a deposed primary, and every directive it
    /// sent was refused (split-brain fencing, DESIGN.md §11).
    pub fenced: bool,
}

/// Per-server agent: local container book + transport to the master.
pub struct SlaveAgent<T: ControlPlane> {
    local: DormSlave,
    server: u32,
    transport: T,
    /// Highest master epoch this agent has ever obeyed — the fence a
    /// deposed primary's directives are checked against.
    max_epoch: u64,
    /// Directive outcomes not yet delivered: shipped as one batch on the
    /// next heartbeat, restored intact when the transport drops the beat.
    pending_acks: Vec<DirectiveAck>,
}

impl<T: ControlPlane> SlaveAgent<T> {
    /// Agent for a preassigned server ordinate (the `--index` path).
    pub fn new(local: DormSlave, server: u32, transport: T) -> Self {
        SlaveAgent { local, server, transport, max_epoch: 0, pending_acks: Vec::new() }
    }

    /// Join without a preassigned ordinate: the master picks a free seat
    /// via the Register RPC (proto v1.2) and this agent heartbeats as
    /// that server from then on.  A typed refusal (duplicate live name,
    /// full cluster, bad capacity) propagates as `Err` — the `--index`
    /// flag remains the manual fallback.
    pub fn register(local: DormSlave, mut transport: T) -> Result<Self> {
        let rsp = transport.call(Request::Register {
            name: local.name.clone(),
            capacity: local.capacity().clone(),
        })?;
        match rsp {
            Response::Registered { server } => {
                log::info!("slave {}: registered as server {server}", local.name);
                Ok(SlaveAgent::new(local, server, transport))
            }
            Response::Error(e) => Err(anyhow::Error::new(e).context("registration rejected")),
            other => bail!("unexpected register response: {other:?}"),
        }
    }

    /// The local container book this agent reports and reconciles.
    pub fn local(&self) -> &DormSlave {
        &self.local
    }

    /// The server ordinate this agent heartbeats as (preassigned via
    /// `--index`, or master-chosen through [`SlaveAgent::register`]).
    pub fn server(&self) -> u32 {
        self.server
    }

    /// Highest master epoch obeyed so far (0 = none reported yet).
    pub fn max_epoch(&self) -> u64 {
        self.max_epoch
    }

    /// One heartbeat round at `now_hours` (non-finite = let the TCP
    /// server stamp the arrival).  Transport failures are `Err` — the
    /// caller decides whether to retry or exit; a directive that fails
    /// to apply is logged and *not* fatal, because the next report shows
    /// the master the true book and reconciliation heals it.  An answer
    /// from a master whose epoch is below the agent's fence applies
    /// *nothing* (`fenced` in the outcome): after a standby takeover the
    /// deposed primary's book is history, and obeying it would fork the
    /// cluster state.
    pub fn step(&mut self, now_hours: f64) -> Result<HeartbeatOutcome> {
        let report = self.local.report();
        let acks = std::mem::take(&mut self.pending_acks);
        let rsp = match self.transport.call(Request::Heartbeat {
            server: self.server,
            now_hours,
            report: Some(report),
            acks: acks.clone(),
        }) {
            Ok(rsp) => rsp,
            Err(e) => {
                // the batch never reached the master; carry it forward
                self.pending_acks = acks;
                return Err(e);
            }
        };
        match rsp {
            Response::HeartbeatAck { alive, directives } => {
                let total = directives.len();
                match self.transport.last_epoch() {
                    Some(e) if e < self.max_epoch => {
                        log::warn!(
                            "slave {}: refusing {total} directive(s) from deposed master \
                             at epoch {e} (fence {})",
                            self.local.name,
                            self.max_epoch
                        );
                        return Ok(HeartbeatOutcome {
                            alive,
                            directives: total,
                            applied: 0,
                            fenced: true,
                        });
                    }
                    Some(e) => self.max_epoch = e,
                    None => {}
                }
                let mut applied = 0;
                for d in directives {
                    let (app, kind) = match &d {
                        Directive::Create { app, .. } => (*app, AckKind::Create),
                        Directive::Destroy { app, .. } => (*app, AckKind::Destroy),
                        Directive::DestroyAll { app } => (*app, AckKind::DestroyAll),
                    };
                    let ok = match self.apply(d) {
                        Ok(()) => {
                            applied += 1;
                            true
                        }
                        Err(e) => {
                            log::warn!(
                                "slave {}: directive failed ({e:#}); reconciling next beat",
                                self.local.name
                            );
                            false
                        }
                    };
                    self.pending_acks.push(DirectiveAck { app, kind, applied: ok });
                }
                Ok(HeartbeatOutcome { alive, directives: total, applied, fenced: false })
            }
            // a typed rejection travels as ProtoError so callers can tell
            // "the master refused us" from "the master is gone"
            Response::Error(e) => Err(anyhow::Error::new(e).context("heartbeat rejected")),
            other => bail!("unexpected heartbeat response: {other:?}"),
        }
    }

    fn apply(&mut self, d: Directive) -> Result<()> {
        match d {
            Directive::Create { app, demand, count } => {
                self.local.create(app, &demand, count)?;
            }
            Directive::Destroy { app, count } => self.local.destroy(app, count)?,
            Directive::DestroyAll { app } => {
                self.local.destroy_all(app);
            }
        }
        Ok(())
    }

    /// Re-register after the master declared this server dead.
    pub fn rejoin(&mut self, now_hours: f64) -> Result<()> {
        match self.transport.call(Request::RecoverServer { server: self.server, now_hours })? {
            Response::Ok => Ok(()),
            Response::Error(e) => Err(anyhow::Error::new(e).context("rejoin rejected")),
            other => bail!("unexpected rejoin response: {other:?}"),
        }
    }

    /// The `dorm slave` process body: beat every `period`, apply
    /// directives, rejoin if declared dead.  A lost transport means the
    /// master went away — the loop ends cleanly with the number of beats
    /// completed.  A typed rejection (e.g. `UnknownServer` from a bad
    /// `--index`) is operator error, not a shutdown: it propagates as
    /// `Err` so the process exits non-zero instead of masquerading as a
    /// clean drain.
    pub fn run(&mut self, period: Duration) -> Result<u64> {
        use crate::proto::ProtoError;
        let mut beats = 0u64;
        loop {
            let out = match self.step(f64::NAN) {
                Ok(out) => out,
                Err(e) if e.downcast_ref::<ProtoError>().is_some() => {
                    return Err(e.context(format!(
                        "master rejected slave {} (server {})",
                        self.local.name, self.server
                    )));
                }
                Err(e) => {
                    log::info!("slave {}: master unreachable ({e:#}); exiting", self.local.name);
                    return Ok(beats);
                }
            };
            beats += 1;
            if out.fenced {
                log::warn!(
                    "slave {}: beat answered by a deposed master (fence epoch {}); \
                     nothing applied",
                    self.local.name,
                    self.max_epoch
                );
            }
            if out.directives > 0 {
                log::info!(
                    "slave {}: applied {}/{} directives; book now {:?}",
                    self.local.name,
                    out.applied,
                    out.directives,
                    self.local.inventory()
                );
            }
            // a fenced (deposed) master's liveness verdict is as stale as
            // its directives: reacting to its alive=false with a
            // RecoverServer would hand the refused master a write
            if !out.alive && !out.fenced {
                log::warn!("slave {}: master declared us dead; rejoining", self.local.name);
                if let Err(e) = self.rejoin(f64::NAN) {
                    // same split as step(): a typed refusal is operator
                    // error; a lost transport is the master going away
                    if e.downcast_ref::<ProtoError>().is_some() {
                        return Err(e.context(format!(
                            "master rejected slave {} (server {})",
                            self.local.name, self.server
                        )));
                    }
                    log::info!(
                        "slave {}: master unreachable during rejoin ({e:#}); exiting",
                        self.local.name
                    );
                    return Ok(beats);
                }
            }
            std::thread::sleep(period);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::app::{AppId, AppSpec, CheckpointStore, Engine};
    use crate::config::{ClusterConfig, DormConfig};
    use crate::master::DormMaster;
    use crate::net::LocalTransport;
    use crate::resources::Res;

    fn master(tag: &str) -> DormMaster {
        let dir = std::env::temp_dir().join(format!("dorm_agent_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        DormMaster::new(
            &ClusterConfig::uniform(2, Res::cpu_gpu_ram(12.0, 0.0, 64.0)),
            DormConfig { theta1: 0.5, theta2: 0.5 },
            CheckpointStore::new(dir).unwrap(),
        )
    }

    fn spec(n_max: u32) -> AppSpec {
        AppSpec {
            executor: Engine::MxNet,
            demand: Res::cpu_gpu_ram(2.0, 0.0, 8.0),
            weight: 1,
            n_max,
            n_min: 1,
            cmd: ["lr".into(), "lr".into()],
        }
    }

    /// The agent's empty book converges on the master's desired state in
    /// one beat, stays converged, and drains on completion — all through
    /// the ControlPlane interface only.
    #[test]
    fn agent_converges_on_master_book() {
        let mut m = master("converge");
        let id = m.submit(spec(12)).unwrap();
        assert_eq!(m.containers_of(id), 12);
        let local = DormSlave::new("slave00", Res::cpu_gpu_ram(12.0, 0.0, 64.0));
        let mut agent = SlaveAgent::new(local, 0, LocalTransport::new(m));

        let out = agent.step(1.0).unwrap();
        assert!(out.alive);
        assert_eq!(out.directives, 1, "one create batch");
        assert_eq!(out.applied, 1);
        assert_eq!(agent.local().count_for(id), 6, "master book has 6 here");

        // converged: second beat is a no-op
        let out = agent.step(2.0).unwrap();
        assert_eq!(out.directives, 0);

        // completion drains the remote book on the next beat
        let rsp = agent.transport.call(Request::Complete { app: id }).unwrap();
        assert_eq!(rsp, Response::Ok);
        let out = agent.step(3.0).unwrap();
        assert_eq!(out.directives, 1);
        assert_eq!(agent.local().count_for(id), 0);
        assert_eq!(agent.local().inventory().len(), 0);
    }

    /// A dead server's heartbeat says so; rejoin restores liveness and
    /// the following beat repopulates the emptied book.
    #[test]
    fn dead_agent_rejoins_and_repopulates() {
        let mut m = master("rejoin");
        let id = m.submit(spec(12)).unwrap();
        m.fail_server(0).unwrap();
        let local = DormSlave::new("slave00", Res::cpu_gpu_ram(12.0, 0.0, 64.0));
        let mut agent = SlaveAgent::new(local, 0, LocalTransport::new(m));

        let out = agent.step(1.0).unwrap();
        assert!(!out.alive, "master must report the dead lease");
        agent.rejoin(1.5).unwrap();
        let out = agent.step(2.0).unwrap();
        assert!(out.alive);
        assert!(out.applied >= 1, "regrown placement lands on this server");
        assert!(agent.local().count_for(id) > 0);
    }

    /// Directive outcomes batch onto the *next* heartbeat — one round
    /// trip carries them all, and the master's counters tick up.
    #[test]
    fn acks_batch_onto_the_next_beat() {
        let mut m = master("acks");
        m.submit(spec(12)).unwrap();
        let local = DormSlave::new("slave00", Res::cpu_gpu_ram(12.0, 0.0, 64.0));
        let mut agent = SlaveAgent::new(local, 0, LocalTransport::new(m));

        let out = agent.step(1.0).unwrap();
        assert_eq!(out.applied, 1);
        assert_eq!(agent.transport.master().directive_acks, 0, "ack rides the NEXT beat");
        assert_eq!(agent.pending_acks.len(), 1);

        agent.step(2.0).unwrap();
        assert_eq!(agent.transport.master().directive_acks, 1);
        assert_eq!(agent.transport.master().directive_nacks, 0);
        assert!(agent.pending_acks.is_empty(), "delivered batch is dropped");
    }

    /// `register()` joins without a preassigned `--index`; heartbeats on
    /// the assigned seat work immediately, and a duplicate live name is
    /// a typed refusal.
    #[test]
    fn register_assigns_a_seat_and_refuses_live_duplicates() {
        let m = master("register");
        let local = DormSlave::new("joiner-a", Res::cpu_gpu_ram(12.0, 0.0, 64.0));
        let mut agent = SlaveAgent::register(local, LocalTransport::new(m)).unwrap();
        assert!(agent.step(1.0).unwrap().alive);
        let rsp = agent
            .transport
            .call(Request::Register {
                name: "joiner-a".into(),
                capacity: Res::cpu_gpu_ram(12.0, 0.0, 64.0),
            })
            .unwrap();
        match rsp {
            Response::Error(e) => {
                assert_eq!(e.code, crate::proto::ErrorCode::AlreadyRegistered)
            }
            other => panic!("duplicate register must be refused, got {other:?}"),
        }
    }

    /// AppId(…) placed by a stale master decision the agent never saw:
    /// the report exposes it and the master orders it destroyed.
    #[test]
    fn stale_local_containers_are_reconciled_away() {
        let m = master("stale");
        let mut local = DormSlave::new("slave00", Res::cpu_gpu_ram(12.0, 0.0, 64.0));
        local.create(AppId(99), &Res::cpu_gpu_ram(1.0, 0.0, 1.0), 2).unwrap();
        let mut agent = SlaveAgent::new(local, 0, LocalTransport::new(m));
        let out = agent.step(1.0).unwrap();
        assert_eq!(out.directives, 1);
        assert_eq!(agent.local().count_for(AppId(99)), 0);
    }
}

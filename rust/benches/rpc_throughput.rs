//! Control-plane saturation throughput (DESIGN.md §15): M concurrent
//! clients of submit/heartbeat/query traffic against the multiplexed
//! server (`serve`) and the thread-per-connection baseline
//! (`serve_legacy`), over loopback TCP.
//!
//! Where `rpc_roundtrip` times one client's unloaded round trip, this
//! bench drives fan-in through [`dorm::net::loadgen`] (the same driver
//! behind `dorm bench rpc-throughput`): every client loops the slave
//! fleet's steady-state mix — mostly lease-only heartbeats, a
//! `QueryState` every 16th call, an occasional submit/complete pair — as
//! fast as the server answers, and the report is the *sustained*
//! aggregate rate with client-observed p50/p99 round-trip latency.
//!
//! Knobs: `DORM_SCHED_SCALE=ci` for the reduced sweep (the CI smoke),
//! `DORM_BENCH_JSON=<path>` to splice an `"rpc"` series into
//! `BENCH_sched.json` (gated by `scripts/check_bench.sh`), and
//! `DORM_RPC_ENFORCE=1` to hard-assert the headline claim — multiplexed
//! at 64 clients sustains >= 4x the legacy req/s without a p99
//! regression — which CI leaves to the baseline gate because shared
//! runners are too noisy for a fixed multiplier.

#[path = "harness/mod.rs"]
mod harness;

use std::time::Duration;

use dorm::app::CheckpointStore;
use dorm::config::{ClusterConfig, DormConfig, NetConfig};
use dorm::master::DormMaster;
use dorm::net::loadgen::{bench_spec, drive, splice_rpc_json, LoadReport, ServerKind};
use dorm::resources::Res;

const SERVERS: u32 = 64;

fn ci_scale() -> bool {
    matches!(std::env::var("DORM_SCHED_SCALE").as_deref(), Ok("ci"))
}

fn master(tag: &str) -> DormMaster {
    let dir = std::env::temp_dir().join(format!("dorm_rpc_tput_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut m = DormMaster::new(
        &ClusterConfig::uniform(SERVERS as usize, Res::cpu_gpu_ram(12.0, 0.0, 64.0)),
        DormConfig { theta1: 0.1, theta2: 0.1 },
        CheckpointStore::new(dir).unwrap(),
    );
    // a live population so heartbeat reconciliation and QueryState have
    // real work to answer with
    for i in 0..8u32 {
        m.submit(bench_spec(i)).unwrap();
    }
    m
}

/// One sweep point: serve fresh state, drive it, tear it down.
fn point(kind: ServerKind, clients: usize, duration: Duration) -> (ServerKind, LoadReport) {
    let net = NetConfig {
        bind_addr: "127.0.0.1:0".into(),
        io_timeout_ms: 10_000,
        ..NetConfig::default()
    };
    let handle = kind
        .serve(master(&format!("{}_{clients}", kind.label())), &net)
        .expect("bind bench server");
    let rep = drive(&handle, &net, SERVERS, clients, duration).expect("load drive");
    handle.stop();
    println!(
        "  {:<6} @ {:>3} clients: {:>8.0} req/s ({:>8.0} hb/s fan-in)  \
         p50 {:>7.1} us  p99 {:>8.1} us  ({} calls in {:.2} s)",
        kind.label(),
        rep.clients,
        rep.req_per_sec,
        rep.heartbeats_per_sec,
        rep.p50_us,
        rep.p99_us,
        rep.calls,
        rep.wall_secs
    );
    (kind, rep)
}

fn main() {
    harness::banner("control-plane saturation throughput (legacy vs multiplexed)");
    let duration = if ci_scale() { Duration::from_millis(1200) } else { Duration::from_secs(4) };
    let fan = 64usize;

    let mut points = Vec::new();
    points.push(point(ServerKind::Legacy, fan, duration));
    points.push(point(ServerKind::Mux, 8, duration));
    points.push(point(ServerKind::Mux, fan, duration));
    if !ci_scale() {
        points.push(point(ServerKind::Mux, 256, duration));
    }

    let legacy =
        &points.iter().find(|(k, p)| *k == ServerKind::Legacy && p.clients == fan).unwrap().1;
    let mux = &points.iter().find(|(k, p)| *k == ServerKind::Mux && p.clients == fan).unwrap().1;
    let speedup = mux.req_per_sec / legacy.req_per_sec.max(1e-9);

    harness::banner("verdict");
    harness::paper_row(
        &format!("multiplexed vs thread-per-conn at {fan} clients"),
        ">= 4x req/s, p99 no worse",
        &format!("{speedup:.2}x req/s, p99 {:.0} vs {:.0} us", mux.p99_us, legacy.p99_us),
    );
    if std::env::var("DORM_RPC_ENFORCE").as_deref() == Ok("1") {
        assert!(
            speedup >= 4.0,
            "multiplexed server sustained only {speedup:.2}x the legacy req/s at {fan} clients"
        );
        assert!(
            mux.p99_us <= legacy.p99_us * 1.25,
            "multiplexed p99 {:.1} us regressed past legacy {:.1} us",
            mux.p99_us,
            legacy.p99_us
        );
        println!("  DORM_RPC_ENFORCE: >= 4x with no p99 regression holds");
    }

    if let Ok(path) = std::env::var("DORM_BENCH_JSON") {
        // same discipline as the replay_rate bench: this bench runs last
        // and owns only its own series in the shared document
        splice_rpc_json(&path, &points, speedup).expect("splice rpc series");
        println!("  spliced rpc series into {path}");
    }
}

//! `dorm` — the leader binary: run the §V simulation, train models through
//! the full three-layer stack, or analyze scheduling latency.  See
//! [`dorm::cli::USAGE`].

use anyhow::Result;

use dorm::app::{AppId, CheckpointStore};
use dorm::baselines::tasklevel::{dorm_local_placement_ms, TaskLevelModel};
use dorm::cli::{Cli, USAGE};
use dorm::ps::{Trainer, TrainerConfig};
use dorm::report;
use dorm::runtime::{ComputeService, Manifest};
use dorm::sim::{fairness_reduction, mean_speedup, utilization_ratio, Experiment};
use dorm::util::{stats, Rng};
use dorm::workload::{app_duration_hours, task_duration_secs, DurationModel};

fn main() {
    dorm::util::logger::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() || args[0] == "help" || args[0] == "--help" {
        print!("{USAGE}");
        return;
    }
    let cli = match Cli::parse(&args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    let result = match cli.command.as_str() {
        "simulate" => cmd_simulate(&cli),
        "replay" => cmd_replay(&cli),
        "churn" => cmd_churn(&cli),
        "fig1" => cmd_fig1(),
        "train" => cmd_train(&cli),
        "latency" => cmd_latency(&cli),
        "master" => cmd_master(&cli),
        "slave" => cmd_slave(&cli),
        "ctl" => cmd_ctl(&cli),
        "bench" => cmd_bench(&cli),
        other => {
            eprintln!("unknown command {other:?}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn cmd_simulate(cli: &Cli) -> Result<()> {
    let seed = cli.u64_flag("seed", 17)?;
    let horizon = cli.f64_flag("horizon", 24.0)?;
    let mut exp = Experiment::paper(seed);
    exp.sim.horizon_hours = horizon;
    println!("§V experiment: 50 apps / 20 slaves / {horizon} h (seed {seed})");
    let runs = exp.run_all();
    let (baseline, dorms) = runs.split_first().unwrap();
    let mut rows = Vec::new();
    for r in &runs {
        rows.push(vec![
            r.label.clone(),
            format!("{:.2}", r.metrics().utilization.mean_over(0.0, horizon)),
            format!("{:.2}", r.metrics().fairness_loss.max()),
            format!("{:.0}", r.metrics().adjustments.last().unwrap_or(0.0)),
            format!("{}", r.outcome.completed),
        ]);
    }
    println!(
        "{}",
        report::table(
            &["system", "mean util", "max fairness loss", "adjusted", "completed"],
            &rows
        )
    );
    for d in dorms {
        println!(
            "{}: util gain {:.2}x | fairness reduction {:.2}x | speedup {:.2}x",
            d.label,
            utilization_ratio(d, baseline, 5.0_f64.min(horizon)),
            fairness_reduction(d, baseline, horizon),
            mean_speedup(d, baseline),
        );
    }
    Ok(())
}

/// Resolve the `[trace]` configuration (trace replay, DESIGN.md §13):
/// `--config FILE` or defaults, then the flag overrides.
fn trace_from_cli(cli: &Cli) -> Result<dorm::config::TraceConfig> {
    use dorm::config::{parse_toml, TraceConfig};
    let mut tc = match cli.flags.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("--config {path}: {e}"))?;
            TraceConfig::from_doc(&parse_toml(&text)?)?
        }
        None => TraceConfig::default(),
    };
    if cli.flags.contains_key("buffer") {
        tc.buffer = cli.u64_flag("buffer", tc.buffer as u64)? as usize;
        if tc.buffer == 0 {
            anyhow::bail!("--buffer must be >= 1");
        }
    }
    if cli.flags.contains_key("time-scale") {
        tc.time_scale = cli.f64_flag("time-scale", tc.time_scale)?;
        if !(tc.time_scale > 0.0 && tc.time_scale.is_finite()) {
            anyhow::bail!("--time-scale must be finite and > 0");
        }
    }
    if cli.flags.contains_key("rate") {
        tc.rate_per_hour = cli.f64_flag("rate", tc.rate_per_hour)?;
        if !(tc.rate_per_hour >= 0.0 && tc.rate_per_hour.is_finite()) {
            anyhow::bail!("--rate must be finite and >= 0");
        }
    }
    if cli.flags.contains_key("window") {
        tc.window = cli.u64_flag("window", tc.window as u64)? as usize;
        if tc.window == 0 {
            anyhow::bail!("--window must be >= 1");
        }
    }
    if cli.flags.contains_key("ms-per-hour") {
        tc.ms_per_hour = cli.f64_flag("ms-per-hour", tc.ms_per_hour)?;
        if !(tc.ms_per_hour >= 0.0 && tc.ms_per_hour.is_finite()) {
            anyhow::bail!("--ms-per-hour must be finite and >= 0");
        }
    }
    Ok(tc)
}

/// `dorm replay`: stream a recorded (or generated) job-arrival trace
/// through the DES or a live master without materializing it
/// (DESIGN.md §13).  The trace source is either `--trace FILE` (schema
/// detected from the CSV header) or `--gen N` (synthesized on the fly
/// from the seeded [`dorm::workload::WorkloadSpec`] stream — the same
/// seed reproduces the same trace everywhere).
fn cmd_replay(cli: &Cli) -> Result<()> {
    use dorm::config::{ClusterConfig, DormConfig, SimConfig};
    use dorm::master::DormMaster;
    use dorm::net::{ControlPlane, FailoverTransport, LocalTransport};
    use dorm::resources::Res;
    use dorm::sim::{DormPolicy, PerfModel};
    use dorm::workload::trace::{
        rate_sweep, record_line, record_of, replay_des, replay_live, LiveOpts, RatePoint,
        ReplayOpts, TraceError, TraceReader, TraceRecord, DORM_HEADER,
    };
    use dorm::workload::WorkloadSpec;
    use std::io::{BufRead, BufReader, Write};

    let tc = trace_from_cli(cli)?;
    let seed = cli.u64_flag("seed", 17)?;
    let mode = cli.str_flag("mode", "des");
    let opts = ReplayOpts::from_config(&tc);

    // the record stream: file (never slurped) or generated (never stored)
    let spec = WorkloadSpec::paper(seed);
    let records: Box<dyn Iterator<Item = std::result::Result<TraceRecord, TraceError>>> =
        match (cli.flags.get("trace"), cli.flags.get("gen")) {
            (Some(_), Some(_)) => anyhow::bail!("--trace and --gen are mutually exclusive"),
            (Some(path), None) => {
                let f = std::fs::File::open(path)
                    .map_err(|e| anyhow::anyhow!("--trace {path}: {e}"))?;
                let reader: Box<dyn BufRead> = Box::new(BufReader::new(f));
                let tr = TraceReader::with_defaults(reader, tc.schema_defaults())?;
                println!("trace {path}: {} schema", tr.schema().name());
                Box::new(tr)
            }
            (None, Some(_)) => {
                let n = cli.u64_flag("gen", 0)? as usize;
                if n == 0 {
                    anyhow::bail!("--gen wants a positive arrival count");
                }
                let rows = spec.rows();
                println!("generating {n} arrivals from seed {seed} (streamed)");
                Box::new(spec.stream().take(n).map(move |w| Ok(record_of(&rows, &w))))
            }
            (None, None) => anyhow::bail!("replay needs --trace FILE or --gen N"),
        };

    // --export: write the stream out in the native schema and stop
    if let Some(path) = cli.flags.get("export") {
        let f = std::fs::File::create(path)
            .map_err(|e| anyhow::anyhow!("--export {path}: {e}"))?;
        let mut out = std::io::BufWriter::new(f);
        writeln!(out, "{DORM_HEADER}")?;
        let mut n = 0u64;
        for rec in records {
            writeln!(out, "{}", record_line(&rec?))?;
            n += 1;
        }
        out.flush()?;
        println!("wrote {n} records to {path}");
        return Ok(());
    }

    let slaves = cli.u64_flag("slaves", 20)? as usize;
    let cap = Res::cpu_gpu_ram(
        cli.f64_flag("cpu", 12.0)?,
        cli.f64_flag("gpu", 0.25)?,
        cli.f64_flag("ram", 128.0)?,
    );
    let cluster = ClusterConfig::uniform(slaves, cap);

    match mode.as_str() {
        "des" => {
            let sim = SimConfig {
                horizon_hours: cli.f64_flag("horizon", 24.0)?,
                seed,
                ..Default::default()
            };
            let pm = PerfModel::default();
            let mut policy = DormPolicy::new(DormConfig::DORM3);
            let rep = replay_des(&mut policy, records, opts, &cluster, &sim, &pm)?;
            println!(
                "des replay: {} records read, {} arrivals in horizon, {} completed",
                rep.records_read, rep.outcome.arrivals, rep.outcome.completed
            );
            println!(
                "streaming: max {} records buffered (cap {}), mean util {:.2}",
                rep.max_buffered,
                tc.buffer,
                rep.outcome.metrics.utilization.mean_over(0.0, sim.horizon_hours)
            );
            if cli.bool_flag("csv") {
                let u = &rep.outcome.metrics.utilization.points;
                let cols: [(&str, Vec<f64>); 2] = [
                    ("t_hours", u.iter().map(|&(t, _)| t).collect::<Vec<_>>()),
                    ("utilization", u.iter().map(|&(_, v)| v).collect::<Vec<_>>()),
                ];
                let path = report::write_csv("replay_des.csv", &cols)?;
                println!("wrote {}", path.display());
            }
        }
        "live" => {
            let live = LiveOpts {
                ms_per_hour: tc.ms_per_hour,
                window: tc.window,
                max_apps: cli.u64_flag("max-apps", 0)?,
            };
            let mut transport: Box<dyn ControlPlane> = match cli.flags.get("connect") {
                Some(addr) => Box::new(FailoverTransport::connect(
                    candidates_of(addr)?,
                    &net_from_cli(cli)?,
                )?),
                None => {
                    let dir = std::env::temp_dir()
                        .join(format!("dorm_replay_{}", std::process::id()));
                    let _ = std::fs::remove_dir_all(&dir);
                    Box::new(LocalTransport::new(DormMaster::new(
                        &cluster,
                        DormConfig::DORM3,
                        CheckpointStore::new(dir)?,
                    )))
                }
            };
            let rep = replay_live(&mut *transport, records, opts, &live)?;
            println!(
                "live replay: {} submitted, {} completed, {} rejected in {:.2?}",
                rep.submitted, rep.completed, rep.rejected, rep.wall
            );
            println!(
                "submit p50 {:.3} ms / p99 {:.3} ms; complete p50 {:.3} ms / p99 {:.3} ms",
                rep.metrics.submit_p50_ms(),
                rep.metrics.submit_p99_ms(),
                rep.metrics.complete_p50_ms(),
                rep.metrics.complete_p99_ms()
            );
            println!("streaming: max {} records buffered (cap {})", rep.max_buffered, tc.buffer);
            if cli.bool_flag("csv") {
                let s = &rep.metrics.submit_ms.points;
                let cols: [(&str, Vec<f64>); 2] = [
                    ("t_hours", s.iter().map(|&(t, _)| t).collect::<Vec<_>>()),
                    ("submit_ms", s.iter().map(|&(_, v)| v).collect::<Vec<_>>()),
                ];
                let path = report::write_csv("replay_live.csv", &cols)?;
                println!("wrote {}", path.display());
            }
        }
        "sweep" => {
            let rates: Vec<f64> = cli
                .str_flag("rates", "50,100,200,400,800")
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse::<f64>()
                        .map_err(|_| anyhow::anyhow!("--rates wants numbers, got {s:?}"))
                })
                .collect::<Result<_>>()?;
            let apps_per_rate = cli.u64_flag("apps-per-rate", 200)? as usize;
            // --gen / --trace pick the *shape* of the swept jobs; the sweep
            // regenerates a fresh stream per rate so every point sees the
            // same work (a file trace is drained once, then reused).
            let pool: Vec<TraceRecord> = {
                let mut v = Vec::with_capacity(apps_per_rate);
                for rec in records.take(apps_per_rate) {
                    v.push(rec?);
                }
                v
            };
            if pool.is_empty() {
                anyhow::bail!("sweep has no records to submit");
            }
            let connect = cli.flags.get("connect").cloned();
            let net = net_from_cli(cli)?;
            let dir =
                std::env::temp_dir().join(format!("dorm_sweep_{}", std::process::id()));
            let mut fresh = 0u32;
            let mut mk = || -> Result<Box<dyn ControlPlane>> {
                match &connect {
                    Some(addr) => {
                        Ok(Box::new(FailoverTransport::connect(candidates_of(addr)?, &net)?))
                    }
                    None => {
                        fresh += 1;
                        let d = dir.join(format!("r{fresh}"));
                        let _ = std::fs::remove_dir_all(&d);
                        Ok(Box::new(LocalTransport::new(DormMaster::new(
                            &cluster,
                            DormConfig::DORM3,
                            CheckpointStore::new(d)?,
                        ))))
                    }
                }
            };
            let mut recs = |_rate: f64| pool.clone();
            println!(
                "rate sweep: {} jobs per rate, window {}, rates {rates:?}/s",
                pool.len(),
                tc.window
            );
            let points = rate_sweep(&mut mk, &mut recs, &rates, tc.window, 0.5)?;
            let rows: Vec<Vec<String>> = points
                .iter()
                .map(|p: &RatePoint| {
                    vec![
                        format!("{:.0}", p.offered_per_sec),
                        format!("{:.0}", p.achieved_per_sec),
                        format!("{:.3}", p.efficiency),
                        format!("{:.1}", p.p50_submit_us),
                        format!("{:.1}", p.p99_submit_us),
                        format!("{}", p.rejected),
                    ]
                })
                .collect();
            println!(
                "{}",
                report::table(
                    &["offered/s", "achieved/s", "efficiency", "p50 us", "p99 us", "rejected"],
                    &rows
                )
            );
            if let Some(knee) = points.iter().find(|p| p.efficiency < 0.9) {
                println!("admission saturates near {:.0}/s", knee.offered_per_sec);
            } else {
                println!("no saturation within the swept rates");
            }
            if cli.bool_flag("csv") {
                let cols: [(&str, Vec<f64>); 5] = [
                    ("offered_per_sec", points.iter().map(|p| p.offered_per_sec).collect()),
                    ("achieved_per_sec", points.iter().map(|p| p.achieved_per_sec).collect()),
                    ("efficiency", points.iter().map(|p| p.efficiency).collect()),
                    ("p50_submit_us", points.iter().map(|p| p.p50_submit_us).collect()),
                    ("p99_submit_us", points.iter().map(|p| p.p99_submit_us).collect()),
                ];
                let path = report::write_csv("replay_sweep.csv", &cols)?;
                println!("wrote {}", path.display());
            }
        }
        other => anyhow::bail!("unknown --mode {other:?} (des | live | sweep)"),
    }
    Ok(())
}

fn cmd_churn(cli: &Cli) -> Result<()> {
    use dorm::config::FaultConfig;
    use dorm::fault::{
        churn_csv_columns, churn_sweep, churn_systems, churn_table, correlated_csv_columns,
        correlated_sweep, correlated_table,
    };
    let seed = cli.u64_flag("seed", 17)?;
    let horizon = cli.f64_flag("horizon", 8.0)?;
    let napps = cli.u64_flag("apps", 16)? as usize;
    let defaults = FaultConfig::default();
    let mut fault = FaultConfig {
        enabled: true,
        mttr_hours: cli.f64_flag("mttr", defaults.mttr_hours)?,
        ckpt_period_hours: cli.f64_flag("ckpt", defaults.ckpt_period_hours)?,
        seed,
        master_fail_at_hours: cli.f64_flag("master-fail", defaults.master_fail_at_hours)?,
        master_takeover_hours: cli.f64_flag("takeover", defaults.master_takeover_hours)?,
        ..defaults
    };
    let list_flag = |key: &str, default: &str| -> Result<Vec<f64>> {
        cli.str_flag(key, default)
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<f64>()
                    .map_err(|_| anyhow::anyhow!("--{key} wants numbers, got {s:?}"))
            })
            .collect()
    };
    let slugged = |system: &str| -> String {
        system
            .chars()
            .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
            .collect()
    };

    if cli.bool_flag("domains") {
        // correlated failure-domain sweep (DESIGN.md §14): whole racks die
        // in one batch; sweep the *domain* MTBF, with independent per-
        // server churn effectively off unless --server-mtbf lowers it
        fault.domains.enabled = true;
        fault.mtbf_hours = cli.f64_flag("server-mtbf", 1e9)?;
        fault.domains.domain_size =
            cli.u64_flag("domain-size", fault.domains.domain_size as u64)? as usize;
        fault.domains.domain_mttr_hours =
            cli.f64_flag("domain-mttr", fault.domains.domain_mttr_hours)?;
        fault.domains.hot_factor = cli.f64_flag("hot-factor", 4.0)?;
        let dmtbfs = list_flag("domain-mtbfs", "2,4,8,16")?;
        println!(
            "correlated churn sweep: {napps} apps / {horizon} h / racks of {} / \
             rack 0 {}x hotter / domain MTTR {} h / domain MTBF {dmtbfs:?} (seed {seed})",
            fault.domains.domain_size, fault.domains.hot_factor, fault.domains.domain_mttr_hours
        );
        let points = correlated_sweep(&fault, seed, horizon, napps, &dmtbfs)?;
        println!("{}", correlated_table(&points));
        if cli.bool_flag("csv") {
            let mut systems: Vec<String> = Vec::new();
            for p in &points {
                if !systems.contains(&p.system) {
                    systems.push(p.system.clone());
                }
            }
            for system in systems {
                let cols = correlated_csv_columns(&points, &system);
                let path =
                    report::write_csv(&format!("churn_domains_{}.csv", slugged(&system)), &cols)?;
                println!("wrote {}", path.display());
            }
        }
        return Ok(());
    }

    let mtbfs = list_flag("mtbfs", "2,4,8,16,32")?;
    println!(
        "churn sweep: {napps} apps / {horizon} h / MTTR {} h / ckpt every {} h / \
         MTBF {mtbfs:?} (seed {seed})",
        fault.mttr_hours, fault.ckpt_period_hours
    );
    let points = churn_sweep(&fault, seed, horizon, napps, &mtbfs)?;
    println!("{}", churn_table(&points));
    if cli.bool_flag("csv") {
        for system in churn_systems(&points) {
            let cols = churn_csv_columns(&points, &system);
            let path = report::write_csv(&format!("churn_{}.csv", slugged(&system)), &cols)?;
            println!("wrote {}", path.display());
        }
    }
    Ok(())
}

fn cmd_fig1() -> Result<()> {
    let model = DurationModel::production();
    let mut rng = Rng::new(1);
    let apps: Vec<f64> = (0..20_000).map(|_| app_duration_hours(&model, &mut rng)).collect();
    let tasks: Vec<f64> = (0..20_000).map(|_| task_duration_secs(&model, &mut rng)).collect();
    println!(
        "app duration:  p10 {:.1}h  p50 {:.1}h  p90 {:.1}h   (paper: 90% > 6h)",
        stats::percentile(&apps, 10.0),
        stats::percentile(&apps, 50.0),
        stats::percentile(&apps, 90.0)
    );
    println!(
        "task duration: p10 {:.2}s  p50 {:.2}s  p90 {:.2}s   (paper: 50% < 1.5s)",
        stats::percentile(&tasks, 10.0),
        stats::percentile(&tasks, 50.0),
        stats::percentile(&tasks, 90.0)
    );
    Ok(())
}

fn cmd_train(cli: &Cli) -> Result<()> {
    let model = cli.str_flag("model", "lr");
    let steps = cli.u64_flag("steps", 100)?;
    let workers = cli.u64_flag("workers", 4)? as u32;
    let lr = cli.f64_flag("lr", 0.1)? as f32;

    let manifest = Manifest::load("artifacts")?;
    let service = ComputeService::start_filtered(&manifest, Some(&[model.as_str()]))?;
    let meta = manifest.model(&model)?;
    println!("training {model}: {} params, {workers} worker slots, {steps} steps", meta.n_params);
    let cfg = TrainerConfig { workers, lr, seed: 1, data_seed: 1, ..Default::default() };
    let mut t = Trainer::new(AppId(1), meta, service.handle(), cfg)?;
    let t0 = std::time::Instant::now();
    for chunk in 0..(steps / 10).max(1) {
        let log = t.run(10.min(steps - chunk * 10))?;
        println!("step {:4}  loss {:.4}", log.step, log.loss);
        if log.step >= steps {
            break;
        }
    }
    println!(
        "{} steps in {:.1?} ({:.0} ms/step)",
        t.current_step(),
        t0.elapsed(),
        t0.elapsed().as_millis() as f64 / t.current_step() as f64
    );
    let stats = service.handle().stats()?;
    let exec_ms = stats.exec_micros as f64 / 1000.0;
    let total_ms = t0.elapsed().as_millis() as f64;
    println!(
        "xla exec time: {:.0} ms of {:.0} ms total ({:.1}% — coordinator overhead {:.1}%)",
        exec_ms,
        total_ms,
        100.0 * exec_ms / total_ms,
        100.0 * (1.0 - exec_ms / total_ms)
    );
    let store = CheckpointStore::new("checkpoints")?;
    let path = t.checkpoint(&store)?;
    println!("checkpoint -> {}", path.display());
    Ok(())
}

/// Resolve the `[net]` configuration for the master/slave/ctl commands:
/// start from `--config FILE` (a TOML file whose `[net]` section is
/// parsed by `NetConfig::from_doc`) or the defaults, then apply the
/// per-run flag overrides (`--frame-kib`, `--io-timeout-ms`).
fn net_from_cli(cli: &Cli) -> Result<dorm::config::NetConfig> {
    use dorm::config::{parse_toml, NetConfig};
    let mut net = match cli.flags.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("--config {path}: {e}"))?;
            NetConfig::from_doc(&parse_toml(&text)?)?
        }
        None => NetConfig::default(),
    };
    if cli.flags.contains_key("frame-kib") {
        let kib = cli.u64_flag("frame-kib", 256)?;
        if kib == 0 {
            anyhow::bail!("--frame-kib must be >= 1");
        }
        net.max_frame_bytes = kib as usize * 1024;
    }
    if cli.flags.contains_key("io-timeout-ms") {
        net.io_timeout_ms = cli.u64_flag("io-timeout-ms", net.io_timeout_ms)?;
    }
    if cli.flags.contains_key("workers") {
        net.workers = cli.u64_flag("workers", net.workers as u64)? as usize;
    }
    if cli.flags.contains_key("max-conns") {
        let n = cli.u64_flag("max-conns", net.max_conns as u64)?;
        if n == 0 {
            anyhow::bail!("--max-conns must be >= 1");
        }
        net.max_conns = n as usize;
    }
    Ok(net)
}

/// Resolve the `[ha]` configuration (master failover, DESIGN.md §11):
/// `--config FILE` or defaults, then the flag overrides.  `--ha` and
/// `--standby` both force HA on.
fn ha_from_cli(cli: &Cli) -> Result<dorm::config::HaConfig> {
    use dorm::config::{parse_toml, HaConfig};
    let mut ha = match cli.flags.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("--config {path}: {e}"))?;
            HaConfig::from_doc(&parse_toml(&text)?)?
        }
        None => HaConfig::default(),
    };
    if cli.bool_flag("ha") || cli.bool_flag("standby") {
        ha.enabled = true;
    }
    if cli.flags.contains_key("snapshot-every") {
        ha.snapshot_every = cli.u64_flag("snapshot-every", ha.snapshot_every)?;
        if ha.snapshot_every == 0 {
            anyhow::bail!("--snapshot-every must be >= 1");
        }
    }
    if cli.flags.contains_key("master-lease-ms") {
        ha.master_lease_ms = cli.u64_flag("master-lease-ms", ha.master_lease_ms)?;
        if ha.master_lease_ms == 0 {
            anyhow::bail!("--master-lease-ms must be >= 1");
        }
    }
    if cli.flags.contains_key("probe-ms") {
        ha.probe_period_ms = cli.u64_flag("probe-ms", ha.probe_period_ms)?;
        if ha.probe_period_ms == 0 {
            anyhow::bail!("--probe-ms must be >= 1");
        }
    }
    Ok(ha)
}

/// Resolve the `[cells]` configuration (sharded scheduler, DESIGN.md
/// §12): `--config FILE` or defaults, then the `--cells` count override.
fn cells_from_cli(cli: &Cli) -> Result<dorm::config::CellsConfig> {
    use dorm::config::{parse_toml, CellsConfig};
    let mut cells = match cli.flags.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("--config {path}: {e}"))?;
            CellsConfig::from_doc(&parse_toml(&text)?)?
        }
        None => CellsConfig::default(),
    };
    if cli.flags.contains_key("cells") {
        cells.count = cli.u64_flag("cells", cells.count as u64)? as usize;
        if cells.count == 0 {
            anyhow::bail!("--cells must be >= 1");
        }
    }
    Ok(cells)
}

/// Split a `--connect` value into the candidate list `FailoverTransport`
/// walks ("addr" or "addr1,addr2,...").
fn candidates_of(addr: &str) -> Result<Vec<String>> {
    let out: Vec<String> = addr
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(String::from)
        .collect();
    if out.is_empty() {
        anyhow::bail!("--connect needs at least one address");
    }
    Ok(out)
}

/// The master candidate list a client (`dorm slave` / `dorm ctl`) walks:
/// an explicit `--connect` wins, else `[ha].candidates` from `--config`,
/// else the default single master.
fn client_candidates(cli: &Cli) -> Result<Vec<String>> {
    if let Some(addr) = cli.flags.get("connect") {
        return candidates_of(addr);
    }
    let ha = ha_from_cli(cli)?;
    if !ha.candidates.is_empty() {
        return Ok(ha.candidates);
    }
    candidates_of("127.0.0.1:4600")
}

/// `dorm master`: serve the control plane over TCP until a `ctl shutdown`
/// arrives (the two-process demo in README.md; DESIGN.md §9).  With
/// `--ha` the master self-checkpoints (and resumes from its newest
/// snapshot on restart); with `--standby` the process instead watches a
/// primary and promotes itself at `epoch + 1` when the primary's lease
/// lapses (DESIGN.md §11).
fn cmd_master(cli: &Cli) -> Result<()> {
    use dorm::config::{ClusterConfig, DormConfig, FaultConfig};
    use dorm::master::DormMaster;
    use dorm::net::StandbyOpts;
    use dorm::proto::{PROTO_MAJOR, PROTO_MINOR};
    use dorm::resources::Res;

    let slaves = cli.u64_flag("slaves", 2)? as usize;
    let cap = Res::cpu_gpu_ram(
        cli.f64_flag("cpu", 12.0)?,
        cli.f64_flag("gpu", 0.0)?,
        cli.f64_flag("ram", 64.0)?,
    );
    let dorm_cfg = DormConfig {
        theta1: cli.f64_flag("theta1", 0.1)?,
        theta2: cli.f64_flag("theta2", 0.1)?,
    };
    let lease_ms = cli.u64_flag("lease-ms", 0)?;
    let mut net = net_from_cli(cli)?;
    net.bind_addr = cli.str_flag("bind", &net.bind_addr);
    net.lease_sweep_ms =
        cli.u64_flag("sweep-ms", if lease_ms > 0 { 250 } else { net.lease_sweep_ms })?;
    let ha = ha_from_cli(cli)?;
    let store = CheckpointStore::new(cli.str_flag("store", "net_checkpoints"))?;

    if cli.bool_flag("standby") {
        let opts = StandbyOpts {
            watch: cli.str_flag("watch", "127.0.0.1:4600"),
            master_lease: std::time::Duration::from_millis(ha.master_lease_ms),
            probe_period: std::time::Duration::from_millis(ha.probe_period_ms),
            snapshot_every: ha.snapshot_every,
            snapshots_retain: ha.snapshots_retain,
        };
        println!(
            "dorm master (standby): watching {} (lease {} ms); will serve on {}",
            opts.watch, ha.master_lease_ms, net.bind_addr
        );
        // blocks until the primary's lease lapses, then promotes + serves
        let handle = dorm::net::run_standby(store, &net, &opts)?;
        let epoch = handle.master().lock().map(|m| m.epoch()).unwrap_or(0);
        println!(
            "dorm master (standby): promoted to epoch {epoch}; listening on {}",
            handle.addr()
        );
        handle.wait();
        println!("dorm master: shutdown complete");
        return Ok(());
    }

    let resumed = if ha.enabled { dorm::master::ha::load_master(&store)? } else { None };
    let mut promote_on_resume = false;
    let (mut master, start_seq) = match resumed {
        Some((m, seq)) => {
            println!(
                "dorm master: resumed from checkpoint (epoch {}, clock {}, {} app(s)); \
                 cluster flags ignored",
                m.epoch(),
                m.state_view(None).clock,
                m.active_apps()
            );
            // a restart cannot know whether a standby promoted while it
            // was down; resuming at the snapshot's epoch could collide
            // with a live promoted master at the *same* term — the one
            // split-brain shape epoch fencing cannot arbitrate.  Taking a
            // fresh term (promote below, once HA is armed) keeps the two
            // distinguishable: clients converge on the higher epoch and
            // the loser's writes are refused.  Promotion also re-anchors
            // the restored lease timestamps into this process's clock.
            promote_on_resume = true;
            (m, seq)
        }
        None => {
            let cells = cells_from_cli(cli)?;
            let racks = cli.u64_flag("racks", 0)? as usize;
            let cluster = if racks > 1 {
                // correlated failure domains (DESIGN.md §14): name the
                // slaves rackK-sJ in contiguous blocks so the master
                // derives its rack topology from the server book itself
                ClusterConfig {
                    servers: (0..slaves)
                        .map(|i| dorm::config::ServerConfig {
                            name: format!("rack{}-s{i}", i * racks / slaves.max(1)),
                            capacity: cap.clone(),
                        })
                        .collect(),
                }
            } else {
                ClusterConfig::uniform(slaves, cap)
            };
            let mut m = if racks > 1 {
                println!(
                    "dorm master: {racks} racks over {slaves} slave(s); \
                     risk-aware placement on"
                );
                DormMaster::with_risk_aware(&cluster, dorm_cfg, 2, store.clone())
            } else if cells.count > 1 {
                println!(
                    "dorm master: sharded into {} cells (rebalance every {} events, \
                     imbalance threshold {})",
                    cells.count.min(slaves.max(1)),
                    cells.rebalance_every,
                    cells.imbalance_threshold
                );
                DormMaster::with_cells(&cluster, dorm_cfg, &cells, store.clone())
            } else {
                DormMaster::new(&cluster, dorm_cfg, store.clone())
            };
            if lease_ms > 0 {
                m = m.with_fault(&FaultConfig {
                    lease_timeout_hours: lease_ms as f64 / 3_600_000.0,
                    ..FaultConfig::default()
                });
            }
            if cli.flags.contains_key("epoch") {
                // failure injection: resurrect a "deposed primary" at an
                // old term (the failover smoke drives the fencing with it)
                m = m.with_epoch(cli.u64_flag("epoch", 1)?);
            }
            (m, 0)
        }
    };
    if ha.enabled {
        master = master.with_ha(ha.snapshot_every, ha.snapshots_retain, start_seq)?;
    }
    if promote_on_resume {
        let epoch = master.promote()?;
        println!("dorm master: resumed as a fresh term, now serving epoch {epoch}");
    }
    let epoch = master.epoch();
    // --legacy-net keeps the thread-per-connection baseline reachable for
    // A/B runs against the multiplexed default (DESIGN.md §15)
    let handle = if cli.bool_flag("legacy-net") {
        dorm::net::serve_legacy(master, &net)?
    } else {
        dorm::net::serve(master, &net)?
    };
    println!(
        "dorm master listening on {} (proto v{PROTO_MAJOR}.{PROTO_MINOR}, epoch {epoch}, \
         {slaves} slaves, lease timeout {}, ha {})",
        handle.addr(),
        if lease_ms > 0 { format!("{lease_ms} ms") } else { "off".into() },
        if ha.enabled { "on" } else { "off" },
    );
    handle.wait();
    println!("dorm master: shutdown complete");
    Ok(())
}

/// `dorm slave`: one per-server agent as its own process, heartbeating
/// its report and applying the master's reconciliation directives.
/// `--connect` takes a comma-separated candidate list (primary first,
/// standbys after): the agent re-dials the list across a master failover
/// and refuses directives from a deposed (stale-epoch) primary.
fn cmd_slave(cli: &Cli) -> Result<()> {
    use dorm::net::{FailoverTransport, SlaveAgent};
    use dorm::resources::Res;
    use dorm::slave::DormSlave;

    let candidates = client_candidates(cli)?;
    let net = net_from_cli(cli)?;
    // --period-ms overrides the [net].heartbeat_period_ms config knob
    let period = cli.u64_flag("period-ms", net.heartbeat_period_ms)?;
    let cap = Res::cpu_gpu_ram(
        cli.f64_flag("cpu", 12.0)?,
        cli.f64_flag("gpu", 0.0)?,
        cli.f64_flag("ram", 64.0)?,
    );
    let transport = FailoverTransport::connect(candidates.clone(), &net)?;
    // with --index the ordinate is preassigned (the original flow, and
    // the fallback for masters predating proto v1.2); without it the
    // master picks a free seat via the Register RPC
    let mut agent = if cli.flags.contains_key("index") {
        let index = cli.u64_flag("index", 0)? as u32;
        let name = cli.str_flag("name", &format!("slave{index:02}"));
        SlaveAgent::new(DormSlave::new(name, cap), index, transport)
    } else {
        let name = cli.str_flag("name", &format!("slave-{}", std::process::id()));
        SlaveAgent::register(DormSlave::new(name, cap), transport)?
    };
    let (name, index) = (agent.local().name.clone(), agent.server());
    println!(
        "dorm slave {name} (server {index}) connected via {candidates:?}, \
         beating every {period} ms"
    );
    let beats = agent.run(std::time::Duration::from_millis(period))?;
    println!("dorm slave {name}: master gone after {beats} beats; exiting");
    Ok(())
}

/// `dorm ctl`: issue one typed request against a running master and
/// print the response (the scriptable harness the CI smoke tests drive).
/// `--connect` takes a comma-separated candidate list; `--min-epoch N`
/// refuses to talk to any master serving an epoch below N — the fencing
/// rule that keeps a deposed primary from accepting writes it can no
/// longer own (DESIGN.md §11).
fn cmd_ctl(cli: &Cli) -> Result<()> {
    use dorm::app::{AppSpec, Engine};
    use dorm::net::{ControlPlane, FailoverTransport};
    use dorm::proto::{Request, Response};
    use dorm::resources::Res;

    let op = cli
        .positional
        .first()
        .map(String::as_str)
        .ok_or_else(|| anyhow::anyhow!("ctl needs an operation (see `dorm help`)"))?;
    let req = match op {
        "submit" => Request::Submit {
            spec: AppSpec {
                executor: Engine::MxNet,
                demand: Res::cpu_gpu_ram(
                    cli.f64_flag("cpu", 2.0)?,
                    cli.f64_flag("gpu", 0.0)?,
                    cli.f64_flag("ram", 8.0)?,
                ),
                weight: cli.u64_flag("weight", 1)? as u32,
                n_min: cli.u64_flag("nmin", 1)? as u32,
                n_max: cli.u64_flag("nmax", 8)? as u32,
                cmd: [cli.str_flag("model", "lr"), cli.str_flag("model", "lr")],
            },
        },
        "complete" => Request::Complete { app: AppId(cli.u64_flag("app", 0)?) },
        // --app N filters to one app; absent = the whole view
        "query" => Request::QueryState {
            app: match cli.flags.get("app") {
                Some(_) => Some(AppId(cli.u64_flag("app", 0)?)),
                None => None,
            },
        },
        "advance" => Request::AdvanceSteps {
            app: AppId(cli.u64_flag("app", 0)?),
            steps: cli.u64_flag("steps", 1)?,
        },
        "checkpoint" => Request::CheckpointApp { app: AppId(cli.u64_flag("app", 0)?) },
        "expire" => Request::ExpireLeases { now_hours: f64::NAN },
        "fail" => Request::FailServer { server: cli.u64_flag("server", 0)? as u32 },
        "recover" => Request::RecoverServer {
            server: cli.u64_flag("server", 0)? as u32,
            now_hours: f64::NAN,
        },
        "shutdown" => Request::Shutdown,
        other => anyhow::bail!("unknown ctl op {other:?} (see `dorm help`)"),
    };
    let net = net_from_cli(cli)?;
    let mut t = FailoverTransport::connect(client_candidates(cli)?, &net)?;
    let min_epoch = cli.u64_flag("min-epoch", 0)?;
    if min_epoch > 0 {
        let seen = t.fence();
        if seen < min_epoch {
            anyhow::bail!(
                "stale epoch: master serves epoch {seen}, --min-epoch {min_epoch} \
                 required (deposed primary refused)"
            );
        }
    }
    match t.call(req)? {
        Response::Submitted { app } => println!("submitted app{}", app.0),
        Response::Ok => println!("ok"),
        Response::Expired { dead } => println!("expired servers {dead:?}"),
        Response::Affected { apps } => {
            println!("affected apps {:?}", apps.iter().map(|a| a.0).collect::<Vec<_>>())
        }
        Response::State(v) => {
            println!(
                "epoch={} clock={} servers={}/{} active={} adjustments={} recoveries={} \
                 util={:.3}",
                v.epoch,
                v.clock,
                v.alive_servers,
                v.total_servers,
                v.active_apps,
                v.total_adjustments,
                v.total_recoveries,
                v.utilization
            );
            for a in &v.apps {
                println!(
                    "app{} {:?} containers={} steps={} ckpt={} adj={} rec={}",
                    a.id.0,
                    a.state,
                    a.containers,
                    a.steps_done,
                    a.ckpt_step,
                    a.adjustments,
                    a.recoveries
                );
            }
        }
        Response::Error(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
        other => println!("{other:?}"),
    }
    Ok(())
}

/// `dorm bench rpc-throughput`: the control-plane saturation sweep from
/// the installed binary — no cargo needed on the operator's box.  Drives
/// `--clients` concurrent closed-loop clients (the slave fleet's
/// steady-state packet mix) against a fresh thread-per-connection server
/// and a fresh multiplexed server, and reports each point's sustained
/// req/s with client-observed p50/p99.  `benches/rpc_throughput.rs`
/// tracks the same driver ([`dorm::net::loadgen`]), so numbers printed
/// here line up with the `rpc` series in `BENCH_sched.json`.
fn cmd_bench(cli: &Cli) -> Result<()> {
    use dorm::config::{ClusterConfig, DormConfig};
    use dorm::master::DormMaster;
    use dorm::net::loadgen::{bench_spec, drive, splice_rpc_json, ServerKind};
    use dorm::resources::Res;

    let op = cli
        .positional
        .first()
        .map(String::as_str)
        .ok_or_else(|| anyhow::anyhow!("bench needs an operation (see `dorm help`)"))?;
    if op != "rpc-throughput" {
        anyhow::bail!("unknown bench op {op:?} (rpc-throughput is the only one)");
    }
    let clients = cli.u64_flag("clients", 64)? as usize;
    let servers = cli.u64_flag("servers", 64)? as u32;
    let secs = cli.f64_flag("seconds", 2.0)?;
    if clients == 0 || servers == 0 {
        anyhow::bail!("--clients and --servers must be >= 1");
    }
    if !(secs > 0.0 && secs.is_finite()) {
        anyhow::bail!("--seconds must be finite and > 0");
    }
    let duration = std::time::Duration::from_secs_f64(secs);
    let mut net = net_from_cli(cli)?;
    net.bind_addr = cli.str_flag("bind", "127.0.0.1:0");
    if !cli.flags.contains_key("io-timeout-ms") {
        // a saturated point holds clients mid-wait longer than the
        // config default tolerates
        net.io_timeout_ms = net.io_timeout_ms.max(10_000);
    }

    let fresh_master = |tag: &str| -> Result<DormMaster> {
        let dir =
            std::env::temp_dir().join(format!("dorm_bench_rpc_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut m = DormMaster::new(
            &ClusterConfig::uniform(servers as usize, Res::cpu_gpu_ram(12.0, 0.0, 64.0)),
            DormConfig { theta1: 0.1, theta2: 0.1 },
            CheckpointStore::new(dir)?,
        );
        for i in 0..8u32 {
            m.submit(bench_spec(i))?;
        }
        Ok(m)
    };

    println!(
        "rpc-throughput: {clients} clients x {secs} s per point, {servers} heartbeat ordinates"
    );
    let mut points = Vec::new();
    for kind in [ServerKind::Legacy, ServerKind::Mux] {
        let handle = kind.serve(fresh_master(kind.label())?, &net)?;
        let rep = drive(&handle, &net, servers, clients, duration)?;
        handle.stop();
        println!(
            "  {:<6} @ {:>3} clients: {:>8.0} req/s ({:>8.0} hb/s fan-in)  p50 {:>7.1} us  \
             p99 {:>8.1} us",
            kind.label(),
            rep.clients,
            rep.req_per_sec,
            rep.heartbeats_per_sec,
            rep.p50_us,
            rep.p99_us
        );
        points.push((kind, rep));
    }
    let speedup = points[1].1.req_per_sec / points[0].1.req_per_sec.max(1e-9);
    println!("multiplexed vs legacy at {clients} clients: {speedup:.2}x sustained req/s");
    if let Some(path) = cli.flags.get("json") {
        splice_rpc_json(path, &points, speedup)?;
        println!("spliced rpc series into {path}");
    }
    Ok(())
}

fn cmd_latency(cli: &Cli) -> Result<()> {
    let nodes = cli.u64_flag("nodes", 100)? as usize;
    let m = TaskLevelModel { nodes, ..Default::default() };
    let mut rng = Rng::new(7);
    let s = m.simulate(300, &mut rng);
    println!(
        "task-level two-level sharing, {nodes} nodes: mean {:.0} ms, p50 {:.0} ms, p99 {:.0} ms",
        s.mean_ms, s.p50_ms, s.p99_ms
    );
    println!("(paper measured ~430 ms at 100 nodes)");
    println!(
        "Dorm local placement (§III-D): {:.3} ms ({:.0}x faster)",
        dorm_local_placement_ms(),
        s.mean_ms / dorm_local_placement_ms()
    );
    Ok(())
}

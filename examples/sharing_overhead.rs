//! Fig. 9b, measured for real: Dorm's sharing overhead from checkpoint/
//! kill/resume cycles on an actual training job.
//!
//! Mirrors the paper's §V-B-5 methodology at laptop scale: run the same
//! LR app (same seeds, same total steps) (a) dedicated — no interruption —
//! and (b) under Dorm-style interruption with 2 random kill/resume cycles,
//! then report the duration inflation. The checkpoint+restart cost is
//! real I/O + PJRT work, not a model.
//!
//! ```bash
//! cargo run --release --example sharing_overhead -- [--steps N]
//! ```

use dorm::app::{AppId, CheckpointStore};
use dorm::ps::{Trainer, TrainerConfig};
use dorm::runtime::{ComputeService, Manifest};
use dorm::util::Rng;

fn main() -> anyhow::Result<()> {
    dorm::util::logger::init();
    let args: Vec<String> = std::env::args().collect();
    let steps: u64 = args
        .iter()
        .position(|a| a == "--steps")
        .and_then(|i| args.get(i + 1))
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(300);

    let manifest = Manifest::load("artifacts")?;
    let service = ComputeService::start_filtered(&manifest, Some(&["lr"]))?;
    let meta = manifest.model("lr")?;
    let cfg = TrainerConfig { workers: 4, lr: 0.3, seed: 3, data_seed: 3, ..Default::default() };

    // (a) dedicated run
    let t0 = std::time::Instant::now();
    let mut ded = Trainer::new(AppId(1), meta, service.handle(), cfg.clone())?;
    ded.run(steps)?;
    let dedicated = t0.elapsed();
    let loss_ded = ded.last_loss().unwrap();

    // (b) same training, interrupted twice at random points
    let store = CheckpointStore::new(std::env::temp_dir().join("dorm_overhead"))?;
    let mut rng = Rng::new(42);
    let mut cuts: Vec<u64> = (0..2).map(|_| rng.range_u64(1, steps - 1)).collect();
    cuts.sort();
    cuts.dedup();

    let t1 = std::time::Instant::now();
    let mut t = Trainer::new(AppId(2), meta, service.handle(), cfg.clone())?;
    let mut done = 0;
    for &cut in &cuts {
        t.run(cut - done)?;
        done = cut;
        // the §III-C-2 cycle: save -> kill -> resume (width unchanged here,
        // isolating pure protocol overhead as in the paper's experiment)
        t.checkpoint(&store)?;
        drop(t);
        t = Trainer::resume(AppId(2), meta, service.handle(), cfg.clone(), &store)?;
    }
    t.run(steps - done)?;
    let interrupted = t1.elapsed();
    let loss_int = t.last_loss().unwrap();

    let overhead = interrupted.as_secs_f64() / dedicated.as_secs_f64() - 1.0;
    println!("dedicated:   {steps} steps in {dedicated:.2?} (final loss {loss_ded:.4})");
    println!(
        "interrupted: {steps} steps + {} kill/resume in {interrupted:.2?} (final loss {loss_int:.4})",
        cuts.len()
    );
    println!("sharing overhead: {:.2}%  (paper: ~5% for >=3h apps)", overhead * 100.0);
    println!("(losses match: |Δ| = {:.2e} — the protocol is semantically free)",
             (loss_ded - loss_int).abs());
    Ok(())
}

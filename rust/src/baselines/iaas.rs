//! IaaS baseline (§II-B, OpenStack-style): the cluster is statically split
//! into one virtual sub-cluster per DCS (engine), and every application
//! runs inside its engine's partition only.
//!
//! The paper's §II-C criticism is twofold: (a) popular distributed-ML
//! systems have no multi-application support, so each engine's virtual
//! cluster runs apps one at a time (manual resource division otherwise);
//! (b) capacity cannot flow between engines, so one busy engine starves
//! while another's servers idle.  This policy models exactly that:
//! engine partitions are fixed at construction, apps are FIFO within
//! their engine, one app per engine at a time at its static container
//! count.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::app::Engine;
use crate::cluster::{place, PlacementInput, ServerId};
use crate::sched::{AllocationUpdate, CmsPolicy, SchedCtx};

/// OpenStack-like engine-partitioned baseline.
#[derive(Debug)]
pub struct IaasPolicy {
    /// Server index -> engine owning that server.
    partition: Vec<Engine>,
}

impl IaasPolicy {
    /// Split `n_servers` proportionally to each engine's share of the
    /// Table II workload (MxNet/TensorFlow heavy, Petuum light).
    pub fn proportional(n_servers: usize) -> Self {
        use Engine::*;
        // Table II app counts per engine: MxNet 21, TensorFlow 21,
        // MPI-Caffe 7, Petuum 1 -> 8/8/3/1 of 20 servers.
        let mut partition = Vec::with_capacity(n_servers);
        let quota = [
            (MxNet, (n_servers as f64 * 0.42).round() as usize),
            (TensorFlow, (n_servers as f64 * 0.42).round() as usize),
            (MpiCaffe, (n_servers as f64 * 0.11).round().max(1.0) as usize),
        ];
        for (engine, count) in quota {
            for _ in 0..count {
                if partition.len() < n_servers {
                    partition.push(engine);
                }
            }
        }
        while partition.len() < n_servers {
            partition.push(Engine::Petuum);
        }
        IaasPolicy { partition }
    }

    fn servers_of(&self, engine: Engine) -> Vec<usize> {
        self.partition
            .iter()
            .enumerate()
            .filter(|(_, &e)| e == engine)
            .map(|(i, _)| i)
            .collect()
    }
}

impl CmsPolicy for IaasPolicy {
    fn name(&self) -> String {
        "iaas".into()
    }

    fn on_change(&mut self, ctx: &SchedCtx) -> Option<AllocationUpdate> {
        let mut assignment: BTreeMap<_, BTreeMap<ServerId, u32>> = BTreeMap::new();

        // keep running apps pinned
        let mut engine_busy: BTreeMap<Engine, bool> = BTreeMap::new();
        for app in ctx.apps.values() {
            if app.containers > 0 {
                assignment.insert(app.id, app.placement.clone());
                engine_busy.insert(app.engine, true);
            }
        }

        // admit the oldest pending app per idle engine, inside the
        // engine's partition only
        let mut pending: Vec<_> = ctx.apps.values().filter(|a| a.containers == 0).collect();
        pending.sort_by(|a, b| a.submit.total_cmp(&b.submit));
        for app in pending {
            let engine = app.engine;
            if engine_busy.get(&engine).copied().unwrap_or(false) {
                continue; // one app per virtual cluster (no multi-app support)
            }
            let servers = self.servers_of(engine);
            if servers.is_empty() {
                continue;
            }
            let caps: Vec<_> = servers
                .iter()
                .map(|&j| ctx.capacities[j].clone())
                .collect();
            let input = PlacementInput {
                app: app.id,
                demand: app.demand.clone(),
                target: app.baseline_n,
                current: BTreeMap::new(),
            };
            if let Some(p) = place(&[input], &caps) {
                // map local server indices back to global ids
                let placed: BTreeMap<ServerId, u32> = p.assignment[&app.id]
                    .iter()
                    .map(|(&local, &c)| (ServerId(servers[local.0]), c))
                    .collect();
                assignment.insert(app.id, placed);
                engine_busy.insert(engine, true);
            }
        }

        Some(AllocationUpdate { assignment: Arc::new(assignment), adjusted: vec![] })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, SimConfig};
    use crate::sim::{run_sim, PerfModel};
    use crate::workload::{table2_rows, WorkloadApp};

    #[test]
    fn partition_covers_all_servers() {
        let p = IaasPolicy::proportional(20);
        assert_eq!(p.partition.len(), 20);
        assert!(!p.servers_of(Engine::MxNet).is_empty());
        assert!(!p.servers_of(Engine::TensorFlow).is_empty());
        assert!(!p.servers_of(Engine::MpiCaffe).is_empty());
    }

    #[test]
    fn one_app_per_engine_at_a_time() {
        // two LR (MxNet) apps: the second must wait even though the
        // TensorFlow partition idles — the IaaS pathology.
        let rows = table2_rows();
        let wl: Vec<WorkloadApp> = (0..2)
            .map(|i| WorkloadApp {
                row: 0,
                tag: "LR".into(),
                submit_hours: i as f64 * 0.1,
                duration_at_baseline_hours: 1.0,
                baseline_n: 4,
            })
            .collect();
        let cfg = ClusterConfig::paper_testbed();
        let sim = SimConfig { horizon_hours: 6.0, ..Default::default() };
        let mut pol = IaasPolicy::proportional(20);
        let out = run_sim(&mut pol, &rows, &wl, &cfg, &sim, &PerfModel::default());
        assert_eq!(out.completed, 2);
        let durs: Vec<f64> = out.metrics.completions.iter().map(|&(_, d)| d).collect();
        assert!((durs[0] - 1.0).abs() < 1e-6);
        assert!(durs[1] > 1.5, "second app queued behind the first: {durs:?}");
    }

    #[test]
    fn utilization_worse_than_static() {
        use crate::baselines::StaticPolicy;
        use crate::sim::Experiment;
        let exp = Experiment::scaled(17, 8.0, 16);
        let iaas = exp.run(&mut IaasPolicy::proportional(20));
        let stat = exp.run(&mut StaticPolicy::new());
        let ui = iaas.metrics().utilization.mean_over(0.0, 8.0);
        let us = stat.metrics().utilization.mean_over(0.0, 8.0);
        assert!(ui <= us + 1e-9, "iaas {ui} should not beat app-level static {us}");
    }
}

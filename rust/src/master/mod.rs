//! DormMaster: the central manager (§III-A-1) driving the live runtime.
//!
//! Owns the cluster bookkeeping and the checkpoint store; talks to
//! per-server [`DormSlave`]s for container lifecycle and to the PS runtime
//! ([`crate::ps::Trainer`]) for the actual training work.  All scheduling
//! goes through a [`CmsPolicy`] — by default Dorm's shared
//! [`crate::sched::AllocationEngine`] (the same code the simulator runs),
//! but any policy, including the [`crate::baselines`], can drive the live
//! master via [`DormMaster::with_policy`].  The §III-C-2 adjustment
//! protocol and the Fig. 5 flow:
//!
//! 1. submission / completion snapshots the cluster and asks the policy;
//! 2. new allocations are enforced by destroying/creating containers;
//! 3. adjusted apps are checkpointed, killed and resumed at the new scale.
//!
//! Server liveness and recovery (`crate::fault`, DESIGN.md §8): slaves
//! renew leases via [`DormMaster::heartbeat`]; [`DormMaster::expire_leases`]
//! declares stale servers dead (the failure-injection harness can force it
//! with [`DormMaster::fail_server`]).  A death reclaims the server's
//! capacity and every partition it hosted, rolls the affected apps back to
//! their last checkpoint (`Degraded`, lost work = steps since the
//! checkpoint), invalidates the policy's capacity-derived caches, and
//! re-drives the allocation engine on the shrunken cluster; re-placed apps
//! resume from the checkpoint store at the newly solved scale
//! (`Recovering` → `Running`).
//!
//! When no compute service is attached (e.g. artifacts not built) the
//! master still performs all resource management — apps are bookkeeping
//! entries without trainers (progress advances via
//! [`DormMaster::advance_steps`], checkpoints persist the step cursor),
//! which is what the control-plane tests use.
//!
//! High availability ([`ha`], DESIGN.md §11): a master armed with
//! [`DormMaster::with_ha`] self-checkpoints through the same
//! [`CheckpointStore`] its apps use — a full [`ha::MasterCheckpoint`]
//! every N mutating dispatches, an append-only WAL of the mutating
//! [`Request`]s in between — so a `--standby` process can rebuild an
//! equivalent master with [`ha::load_master`] and take over at
//! `epoch + 1` ([`DormMaster::promote`]).  Every response carries the
//! serving epoch; slaves and `dorm ctl` refuse a deposed (lower-epoch)
//! primary's writes.

pub mod ha;

use std::collections::{BTreeMap, VecDeque};
use std::fmt;

use anyhow::{anyhow, bail, Context, Result};

use crate::app::{AppId, AppSpec, AppState, Checkpoint, CheckpointStore};
use crate::cluster::ServerId;
use crate::config::{CellsConfig, ClusterConfig, DormConfig, FaultConfig};
use crate::fault::{DomainTopology, LeaseTable, RecoveryLog};
use crate::optimizer::SolveMode;
use crate::proto::{
    self, AppView, Directive, DirectiveAck, ErrorCode, ProtoError, Request, Response,
    StateView,
};
use crate::ps::{Trainer, TrainerConfig};
use crate::resources::Res;
use crate::runtime::{ComputeHandle, Manifest};
use crate::sched::{
    AllocationUpdate, CellScheduler, CellView, CmsPolicy, DormPolicy, SchedApp, SchedCtx,
};
use crate::slave::{DormSlave, SlaveReport};

/// One application under management.
pub struct ManagedApp {
    pub id: AppId,
    pub spec: AppSpec,
    pub state: AppState,
    /// Model name (from `cmd[0]`) when a compute service is attached.
    pub model: Option<String>,
    pub trainer: Option<Trainer>,
    /// Kill/resume cycles this app went through (Fig. 9b bookkeeping).
    pub adjustments: u32,
    /// Failure-recovery cycles (server deaths survived; `crate::fault`).
    pub recoveries: u32,
    /// BSP steps completed (trainer step when one is attached, otherwise
    /// advanced by [`DormMaster::advance_steps`]).
    pub steps_done: u64,
    /// Step of the latest checkpoint; a server death rolls `steps_done`
    /// back here and the difference is the lost work.
    pub ckpt_step: u64,
    /// While `Degraded`: whether a digest-valid checkpoint existed at
    /// failure time (probed once by `fail_servers`, consumed by the
    /// recovery resume so it need not re-read the store).
    ckpt_restorable: bool,
}

/// Write `app`'s checkpoint — trainer parameters when one is attached,
/// otherwise a bookkeeping snapshot of the step cursor (so the fault path
/// can measure lost work without a compute service) — update the cursor,
/// and apply retention.  Shared by the adjustment prologue and periodic
/// checkpointing so the two can never diverge.
fn save_checkpoint(store: &CheckpointStore, retain: usize, app: &mut ManagedApp) -> Result<()> {
    let written = if let Some(trainer) = &app.trainer {
        let path = trainer.checkpoint(store).context("checkpoint")?;
        app.steps_done = trainer.current_step();
        path
    } else {
        store
            .save(&Checkpoint {
                app: app.id,
                step: app.steps_done,
                model: app.spec.cmd[0].clone(),
                loss: 0.0,
                params: Vec::new(),
            })
            .context("checkpoint")?
    };
    app.ckpt_step = app.steps_done;
    // the file just written is digest-valid by construction, so retention
    // can skip the newest-good re-scan (prune_after_save vs prune)
    store.prune_after_save(app.id, retain, &written)?;
    Ok(())
}

/// Shorthand for a typed control-plane error response.
fn err(code: ErrorCode, detail: impl fmt::Display) -> Response {
    Response::Error(ProtoError::new(code, detail))
}

/// Retry-dedupe memory: how many `(retry id → response)` pairs the master
/// remembers (v1.3).  Sized for the re-send window of a failover re-dial
/// (one in-flight mutation per client, a handful of clients), not as a
/// general idempotency ledger.
const DEDUPE_CAP: usize = 64;

/// The central manager.
pub struct DormMaster {
    pub slaves: Vec<DormSlave>,
    policy: Box<dyn CmsPolicy>,
    store: CheckpointStore,
    compute: Option<(ComputeHandle, Manifest)>,
    apps: BTreeMap<AppId, ManagedApp>,
    next_id: u64,
    /// Event counter: one tick per mutating control-plane event (submit,
    /// complete, fail_server, recover_server).  The master has no wall
    /// clock; this is its monotone "now" for the snapshot FIFO key and the
    /// recovery log (durations there are *events elapsed*, not hours —
    /// unlike the DES, whose log speaks simulated hours).
    clock: u64,
    /// Total adjusted-app count (Eq. 4 accumulated).
    pub total_adjustments: u32,
    /// Completed failure-recovery cycles across all apps.
    pub total_recoveries: u32,
    lease: LeaseTable,
    /// Per-seat registration bit ([`Request::Register`]).  A `--index`
    /// slave heartbeating a preassigned ordinate never registers — the
    /// bit only guards self-registered seats against duplicate joins.
    registered: Vec<bool>,
    /// Directive outcomes batch-acked on heartbeats (v1.2 telemetry).
    pub directive_acks: u64,
    /// Acks whose directive the slave tried and failed to apply.
    pub directive_nacks: u64,
    recovery_log: RecoveryLog,
    /// Checkpoint retention: newest N per app (`FaultConfig::ckpt_retain`).
    ckpt_retain: usize,
    /// Epoch (term) number: bumped by a standby takeover ([`Self::promote`]);
    /// carried on every response so peers can fence off a deposed primary.
    epoch: u64,
    /// Dorm thresholds this master was built with (persisted in the master
    /// checkpoint so a standby can rebuild the same policy; the defaults
    /// when the master runs an arbitrary [`CmsPolicy`]).
    dorm_cfg: DormConfig,
    /// Self-checkpointing state when HA is armed ([`Self::with_ha`]).
    ha: Option<ha::HaLog>,
    /// Recent `(retry id → response)` pairs ([`Self::dispatch_rid`], v1.3):
    /// a re-sent `Submit`/`Complete` carrying a seen id gets the cached
    /// response instead of a second application.  Rebuilt from WAL replay
    /// on an HA restore (the journal records requests *with* their rid).
    dedupe: VecDeque<(u64, Response)>,
}

impl DormMaster {
    /// A master running the paper's system: the shared allocation engine
    /// with the given θ thresholds.
    pub fn new(
        cluster: &ClusterConfig,
        dorm: DormConfig,
        store: CheckpointStore,
    ) -> Self {
        let mut m = Self::with_policy(
            cluster,
            Box::new(DormPolicy::with_mode(dorm, SolveMode::Heuristic)),
            store,
        );
        m.dorm_cfg = dorm;
        m
    }

    /// As [`Self::new`], with risk-aware placement armed (DESIGN.md §14):
    /// failure domains are derived from the configured slave names
    /// (`rack1-a`/`rack1-b` share rack `rack1`), and an online
    /// [`crate::fault::MtbfEstimator`] — fed by lease expiries,
    /// `FailServer`/`RecoverServer` events and forced failures — steers
    /// equal-slack placement ties away from racks with observed failures.
    /// Allocation *totals* are untouched (the risk term is a tie-break
    /// inside [`crate::cluster::SpreadCtx`]), so the P2 solve is
    /// decision-identical to [`Self::new`]; only container placement moves.
    pub fn with_risk_aware(
        cluster: &ClusterConfig,
        dorm: DormConfig,
        racks_per_power: usize,
        store: CheckpointStore,
    ) -> Self {
        let names: Vec<&str> = cluster.servers.iter().map(|s| s.name.as_str()).collect();
        let topo = DomainTopology::from_names(&names, racks_per_power);
        let mut policy = DormPolicy::with_mode(dorm, SolveMode::Heuristic);
        policy.enable_risk_aware(topo);
        let mut m = Self::with_policy(cluster, Box::new(policy), store);
        m.dorm_cfg = dorm;
        m
    }

    /// A master running the sharded scheduler (`[cells]` config,
    /// DESIGN.md §12): the servers are partitioned into cells solved in
    /// parallel, behind the same [`CmsPolicy`] seam.  With `count = 1`
    /// this is decision-identical to [`Self::new`] (`tests/cells.rs`).
    pub fn with_cells(
        cluster: &ClusterConfig,
        dorm: DormConfig,
        cells: &CellsConfig,
        store: CheckpointStore,
    ) -> Self {
        let n = cluster.servers.len();
        let mut m = Self::with_policy(
            cluster,
            Box::new(CellScheduler::new(dorm, *cells, n)),
            store,
        );
        m.dorm_cfg = dorm;
        m
    }

    /// Per-cell observability when the policy shards the cluster
    /// (`None` under an unsharded policy).
    pub fn cell_views(&self) -> Option<Vec<CellView>> {
        self.policy.cell_views()
    }

    /// A master driven by an arbitrary [`CmsPolicy`] — the same objects the
    /// simulator runs (Dorm, static/Swarm, Mesos app-level, IaaS, ...).
    pub fn with_policy(
        cluster: &ClusterConfig,
        policy: Box<dyn CmsPolicy>,
        store: CheckpointStore,
    ) -> Self {
        let n = cluster.servers.len();
        DormMaster {
            slaves: cluster
                .servers
                .iter()
                .map(|s| DormSlave::new(s.name.clone(), s.capacity.clone()))
                .collect(),
            policy,
            store,
            compute: None,
            apps: BTreeMap::new(),
            next_id: 0,
            clock: 0,
            total_adjustments: 0,
            total_recoveries: 0,
            // leases never expire until a [fault] config opts in; failures
            // can still be forced through fail_server
            lease: LeaseTable::new(n, f64::INFINITY),
            registered: vec![false; n],
            directive_acks: 0,
            directive_nacks: 0,
            recovery_log: RecoveryLog::new(),
            ckpt_retain: FaultConfig::default().ckpt_retain,
            epoch: 1,
            dorm_cfg: DormConfig { theta1: 0.1, theta2: 0.1 },
            ha: None,
            dedupe: VecDeque::new(),
        }
    }

    /// Attach the PJRT compute service: submitted apps now get trainers.
    pub fn with_compute(mut self, handle: ComputeHandle, manifest: Manifest) -> Self {
        self.compute = Some((handle, manifest));
        self
    }

    /// Apply a `[fault]` config: lease timeout + checkpoint retention.
    pub fn with_fault(mut self, cfg: &FaultConfig) -> Self {
        self.lease = LeaseTable::new(self.slaves.len(), cfg.lease_timeout_hours);
        self.ckpt_retain = cfg.ckpt_retain;
        self
    }

    // ---- high availability (`ha`, DESIGN.md §11) ------------------------

    /// This master's epoch (term) number.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Start at an explicit epoch (failure injection / testing — e.g. the
    /// failover smoke resurrects a "deposed primary" at the old term).
    pub fn with_epoch(mut self, epoch: u64) -> Self {
        self.epoch = epoch.max(1);
        self
    }

    /// Arm self-checkpointing: a full [`ha::MasterCheckpoint`] is written
    /// now (so a standby always has a base), then after every
    /// `snapshot_every`-th mutating dispatch, with an append-only WAL of
    /// the mutating requests in between; `retain` bounds the snapshot
    /// files kept.  `start_seq` continues the sequence of a restored
    /// master (0 for a fresh one).
    pub fn with_ha(mut self, snapshot_every: u64, retain: usize, start_seq: u64) -> Result<Self> {
        self.ha = Some(ha::HaLog::new(self.store.clone(), snapshot_every, retain, start_seq));
        self.force_snapshot()?;
        Ok(self)
    }

    /// Standby takeover: bump the epoch, re-anchor alive leases into the
    /// new process's clock domain (time 0 — its wall clock starts at
    /// serve time; keeping the deposed primary's timestamps would defer
    /// expiry arbitrarily), and persist a snapshot at the new epoch so
    /// the deposed primary's stale WAL appends are fenced off on any
    /// later recovery.  Returns the new epoch.
    pub fn promote(&mut self) -> Result<u64> {
        self.epoch += 1;
        self.reanchor_leases();
        // the restored policy's caches (if any) predate the takeover
        self.policy.on_capacity_change();
        self.force_snapshot()?;
        Ok(self.epoch)
    }

    /// Re-anchor every alive lease at time 0 — the start of *this*
    /// process's clock domain (the TCP server stamps sweep times from its
    /// own `Instant`).  Any restored master that starts serving in a new
    /// process needs this, with or without an epoch bump: restored
    /// renewal timestamps live in the dead process's clock, where they
    /// read as far in the future and would defer dead-slave detection by
    /// up to the old process's whole uptime.  [`Self::promote`] calls it;
    /// the `--ha` crash-restart resume path calls it directly.  Faithful
    /// same-process restores (tests) deliberately skip it.
    pub fn reanchor_leases(&mut self) {
        for j in 0..self.slaves.len() {
            if self.lease.is_alive(j) {
                self.lease.mark_alive(j, 0.0);
            }
        }
    }

    /// Write a full master snapshot immediately (no-op without HA).
    pub fn force_snapshot(&mut self) -> Result<()> {
        if self.ha.is_none() {
            return Ok(());
        }
        let snap = ha::snapshot_state(self);
        let log = self.ha.as_mut().expect("checked above");
        log.write_snapshot(snap)
    }

    /// WAL/snapshot bookkeeping after one mutating dispatch: barrier
    /// requests (the ones whose handling *reads* the checkpoint store, so
    /// replay later would see different files) force a full snapshot;
    /// everything else appends to the WAL until the cadence rolls over.
    /// HA persistence failures are logged, never surfaced to the peer —
    /// serving degraded beats refusing work.
    fn ha_commit(&mut self, encoded_req: Vec<u8>, barrier: bool) {
        let epoch = self.epoch;
        let need_snapshot = match self.ha.as_ref() {
            None => return,
            Some(log) => barrier || log.pending_records() + 1 >= log.snapshot_every(),
        };
        let result = if need_snapshot {
            self.ha.as_mut().expect("armed").bump_seq();
            let r = self.force_snapshot();
            if r.is_err() {
                // keep the journal contiguous: only this event is lost to
                // recovery, not everything appended after it
                self.ha.as_mut().expect("armed").rollback_seq();
            }
            r
        } else {
            self.ha.as_mut().expect("armed").append(epoch, &encoded_req)
        };
        if let Err(e) = result {
            log::warn!("HA persistence failed (serving continues): {e:#}");
        }
    }

    // ---- the control-plane API (`crate::proto`, DESIGN.md §9) -----------

    /// The single control-plane entry point: every master↔slave and
    /// harness↔master interaction is a [`Request`] consumed here and a
    /// [`Response`] produced here.  The legacy `pub fn` surface
    /// (`submit`, `complete`, `heartbeat`, ...) is the set of helpers
    /// behind this method; transports ([`crate::net`]) differ only in how
    /// the messages travel.  Infallible by design — failures become
    /// [`Response::Error`] with a typed [`ErrorCode`], so a remote peer
    /// always gets a decodable answer.
    ///
    /// When HA is armed ([`Self::with_ha`]), every mutating request is
    /// journaled *after* handling — success or typed error alike, since a
    /// replay reproduces the same deterministic outcome either way —
    /// through [`Self::ha_commit`] (WAL append, amortized full snapshots).
    pub fn dispatch(&mut self, req: Request) -> Response {
        self.dispatch_rid(req, None)
    }

    /// [`Self::dispatch`] with an optional client retry id (v1.3).  A
    /// `Submit`/`Complete` whose id was seen before returns the remembered
    /// response *without re-running the handler or journaling* — the
    /// idempotency guard that keeps a `FailoverTransport` re-send across a
    /// takeover re-dial from double-applying the mutation.  Other request
    /// kinds ignore the id (mirroring the wire format, which only stamps
    /// the two re-sendable mutations).  When HA is armed, the journal
    /// records the request *with* its rid, so a restored master rebuilds
    /// the same dedupe memory from WAL replay.
    pub fn dispatch_rid(&mut self, req: Request, rid: Option<u64>) -> Response {
        let rid = match req {
            Request::Submit { .. } | Request::Complete { .. } => rid,
            _ => None,
        };
        if let Some(id) = rid {
            if let Some((_, cached)) = self.dedupe.iter().find(|(seen, _)| *seen == id) {
                return cached.clone();
            }
        }
        let action = if self.ha.is_some() { ha::HaAction::of(&req) } else { ha::HaAction::Skip };
        let encoded = match action {
            ha::HaAction::Append => Some(proto::wire::encode_request_rid(&req, rid)),
            _ => None,
        };
        let rsp = self.dispatch_inner(req);
        match (action, &rsp) {
            (ha::HaAction::Skip, _) => {}
            // the routine lease sweep: nothing expired, nothing mutated —
            // snapshotting 4x/s on an idle cluster would defeat the WAL
            // amortization (a sweep that *did* kill servers falls through
            // to the barrier below)
            (ha::HaAction::Barrier, Response::Expired { dead }) if dead.is_empty() => {}
            // barrier requests refused before their handler ran (unknown
            // server, non-finite time) mutated nothing; an Internal error
            // can follow a partial mutation, so it still snapshots.  An
            // empty Affected is NOT exempt: a server can die hosting zero
            // apps and that death must be durable.
            (ha::HaAction::Barrier, Response::Error(e))
                if e.code != ErrorCode::Internal => {}
            (ha::HaAction::Append, _) => {
                self.ha_commit(encoded.expect("encoded above"), false)
            }
            (ha::HaAction::Barrier, _) => self.ha_commit(Vec::new(), true),
        }
        if let Some(id) = rid {
            if self.dedupe.len() >= DEDUPE_CAP {
                self.dedupe.pop_front();
            }
            self.dedupe.push_back((id, rsp.clone()));
        }
        rsp
    }

    /// Coalesced heartbeat processing for the multiplexed server
    /// (DESIGN.md §15): drain a run of [`Request::Heartbeat`]s that
    /// arrived within one poll tick into one lease-table pass with at
    /// most one re-solve.  Per-beat observable semantics match
    /// [`Self::dispatch`] — same validation and typed errors, same ack
    /// counting, same `alive` verdict (taken before that beat's renewal,
    /// in arrival order) and the same idempotent desired-state
    /// reconciliation — but the per-beat `reallocate` collapses, so N
    /// capacity events in one batch cost one solve instead of N.  A
    /// non-heartbeat slipped into the batch falls back to plain
    /// [`Self::dispatch`].  When HA is armed every beat is journaled in
    /// arrival order exactly as sequential dispatch would, so WAL replay
    /// converges on the same lease and capacity state.
    pub fn dispatch_heartbeats(&mut self, beats: Vec<Request>) -> Vec<Response> {
        if beats.len() <= 1 {
            return beats.into_iter().map(|r| self.dispatch(r)).collect();
        }
        // what each beat still owes after the shared phases
        enum Slot {
            Done(Response),
            Pending { j: usize, alive: bool, report: Option<SlaveReport>, adopted: bool },
        }
        let mut slots: Vec<Slot> = Vec::with_capacity(beats.len());
        let mut need_resolve = false;
        // phase 1: validate, count acks, take alive verdicts and renew
        // leases in arrival order; adopt sane capacity changes but defer
        // the shared re-solve
        for req in beats {
            let hb = match req {
                hb @ Request::Heartbeat { .. } => hb,
                other => {
                    slots.push(Slot::Done(self.dispatch(other)));
                    continue;
                }
            };
            if self.ha.is_some() {
                // Heartbeat is an Append action; journal it before the
                // destructuring below consumes the fields
                self.ha_commit(proto::wire::encode_request_rid(&hb, None), false);
            }
            let Request::Heartbeat { server, now_hours, report, acks } = hb else {
                unreachable!("matched above")
            };
            let Some(j) = self.known_server(server) else {
                slots.push(Slot::Done(err(
                    ErrorCode::UnknownServer,
                    format!("unknown server {server}"),
                )));
                continue;
            };
            if !now_hours.is_finite() {
                slots.push(Slot::Done(err(
                    ErrorCode::InvalidArgument,
                    "heartbeat time must be finite by dispatch time \
                     (only the TCP server stamps arrival times)",
                )));
                continue;
            }
            self.note_acks(j, &acks);
            let alive = self.lease.is_alive(j);
            self.lease.renew(j, now_hours);
            let mut adopted = false;
            if let Some(r) = &report {
                let sane = r.capacity.m() == self.slaves[j].capacity().m()
                    && r.capacity.0.iter().all(|c| c.is_finite() && *c >= 0.0);
                if !sane {
                    log::warn!(
                        "server {j} reports unusable capacity {}; keeping {}",
                        r.capacity,
                        self.slaves[j].capacity()
                    );
                }
                if alive && sane && r.capacity != *self.slaves[j].capacity() {
                    log::info!(
                        "server {j} reports capacity {} (book had {}); re-solving",
                        r.capacity,
                        self.slaves[j].capacity()
                    );
                    self.clock += 1;
                    if let Err(e) = self.slaves[j].set_capacity(r.capacity.clone()) {
                        slots.push(Slot::Done(err(ErrorCode::Internal, e)));
                        continue;
                    }
                    self.policy.on_capacity_change();
                    adopted = true;
                    need_resolve = true;
                }
            }
            slots.push(Slot::Pending { j, alive, report, adopted });
        }
        // phase 2: the coalesced re-solve — N capacity events, one solve
        let resolve_err = if need_resolve {
            self.reallocate().err().map(|e| format!("{e:#}"))
        } else {
            None
        };
        // phase 3: reconcile each beat against the settled book
        slots
            .into_iter()
            .map(|slot| match slot {
                Slot::Done(rsp) => rsp,
                Slot::Pending { adopted: true, .. } if resolve_err.is_some() => {
                    err(ErrorCode::Internal, resolve_err.as_deref().expect("checked"))
                }
                Slot::Pending { j, alive, report, .. } => {
                    let directives = report
                        .map(|r| self.reconcile(j, &r.containers))
                        .unwrap_or_default();
                    Response::HeartbeatAck { alive, directives }
                }
            })
            .collect()
    }

    fn dispatch_inner(&mut self, req: Request) -> Response {
        match req {
            Request::Hello { major, minor } => match proto::negotiate(major, minor) {
                Ok(()) => Response::HelloAck {
                    major: proto::PROTO_MAJOR,
                    minor: proto::PROTO_MINOR,
                },
                Err(e) => Response::Error(e),
            },
            Request::Submit { spec } => {
                // the typed split a retrying client depends on: a bad
                // tuple is permanent (InvalidSpec), anything that breaks
                // past validation (store IO, solver) is Internal
                if let Err(e) = spec.validate() {
                    return err(ErrorCode::InvalidSpec, e);
                }
                if let Some(rsp) = self.check_demand(&spec.demand, ErrorCode::InvalidSpec) {
                    return rsp;
                }
                match self.submit(spec) {
                    Ok(id) => Response::Submitted { app: id },
                    Err(e) => err(ErrorCode::Internal, e),
                }
            }
            Request::Complete { app } => match self.apps.get(&app) {
                None => err(ErrorCode::UnknownApp, format!("unknown app {app}")),
                Some(a) if a.state.is_terminal() => {
                    err(ErrorCode::InvalidState, format!("{app} already terminal"))
                }
                Some(_) => match self.complete(app) {
                    Ok(()) => Response::Ok,
                    Err(e) => err(ErrorCode::Internal, e),
                },
            },
            Request::Heartbeat { server, now_hours, report, acks } => {
                let Some(j) = self.known_server(server) else {
                    return err(ErrorCode::UnknownServer, format!("unknown server {server}"));
                };
                if !now_hours.is_finite() {
                    return err(
                        ErrorCode::InvalidArgument,
                        "heartbeat time must be finite by dispatch time \
                         (only the TCP server stamps arrival times)",
                    );
                }
                self.note_acks(j, &acks);
                match self.heartbeat_report(j, now_hours, report.as_ref()) {
                    Ok((alive, directives)) => Response::HeartbeatAck { alive, directives },
                    Err(e) => err(ErrorCode::Internal, e),
                }
            }
            Request::Register { name, capacity } => self.register(&name, capacity),
            Request::CreateContainers { server, app, demand, count } => {
                let Some(j) = self.known_server(server) else {
                    return err(ErrorCode::UnknownServer, format!("unknown server {server}"));
                };
                if count == 0 {
                    return err(ErrorCode::InvalidArgument, "count must be >= 1");
                }
                // a sane non-zero demand also bounds `count`: the slave's
                // capacity check fails before any allocation happens, so
                // a hostile count cannot drive memory use
                if let Some(rsp) = self.check_demand(&demand, ErrorCode::InvalidArgument) {
                    return rsp;
                }
                match self.slaves[j].create(app, &demand, count) {
                    Ok(_) => Response::Ok,
                    Err(e) => err(ErrorCode::InvalidState, e),
                }
            }
            Request::Destroy { server, app, count } => {
                let Some(j) = self.known_server(server) else {
                    return err(ErrorCode::UnknownServer, format!("unknown server {server}"));
                };
                match count {
                    None => {
                        self.slaves[j].destroy_all(app);
                        Response::Ok
                    }
                    Some(n) => match self.slaves[j].destroy(app, n) {
                        Ok(()) => Response::Ok,
                        Err(e) => err(ErrorCode::InvalidState, e),
                    },
                }
            }
            Request::CheckpointApp { app } => match self.apps.get(&app) {
                None => err(ErrorCode::UnknownApp, format!("unknown app {app}")),
                Some(a) if a.state != AppState::Running => err(
                    ErrorCode::InvalidState,
                    format!("{app} is {:?}, not Running", a.state),
                ),
                Some(_) => match self.checkpoint_app(app) {
                    Ok(()) => Response::Ok,
                    Err(e) => err(ErrorCode::Internal, e),
                },
            },
            Request::AdvanceSteps { app, steps } => match self.apps.get(&app) {
                None => err(ErrorCode::UnknownApp, format!("unknown app {app}")),
                Some(_) => match self.advance_steps(app, steps) {
                    Ok(()) => Response::Ok,
                    Err(e) => err(ErrorCode::InvalidState, e),
                },
            },
            Request::Reallocate => match self.reallocate() {
                Ok(()) => Response::Ok,
                Err(e) => err(ErrorCode::Internal, e),
            },
            Request::ExpireLeases { now_hours } => {
                if !now_hours.is_finite() {
                    return err(ErrorCode::InvalidArgument, "expiry time must be finite");
                }
                match self.expire_leases(now_hours) {
                    Ok(dead) => Response::Expired {
                        dead: dead.into_iter().map(|j| j as u32).collect(),
                    },
                    Err(e) => err(ErrorCode::Internal, e),
                }
            }
            Request::FailServer { server } => {
                let Some(j) = self.known_server(server) else {
                    return err(ErrorCode::UnknownServer, format!("unknown server {server}"));
                };
                match self.fail_server(j) {
                    Ok(apps) => Response::Affected { apps },
                    Err(e) => err(ErrorCode::Internal, e),
                }
            }
            Request::RecoverServer { server, now_hours } => {
                let Some(j) = self.known_server(server) else {
                    return err(ErrorCode::UnknownServer, format!("unknown server {server}"));
                };
                if !now_hours.is_finite() {
                    return err(ErrorCode::InvalidArgument, "recovery time must be finite");
                }
                match self.recover_server_at(j, now_hours) {
                    Ok(()) => Response::Ok,
                    Err(e) => err(ErrorCode::Internal, e),
                }
            }
            Request::QueryState { app } => {
                if let Some(id) = app {
                    if !self.apps.contains_key(&id) {
                        return err(ErrorCode::UnknownApp, format!("unknown app {id}"));
                    }
                }
                Response::State(self.state_view(app))
            }
            // serving loops interpret Shutdown; for the master itself it
            // is an acknowledged no-op (nothing to tear down in-process)
            Request::Shutdown => Response::Ok,
        }
    }

    /// Validate a wire-side server ordinate against the cluster size.
    fn known_server(&self, server: u32) -> Option<usize> {
        let j = server as usize;
        (j < self.slaves.len()).then_some(j)
    }

    /// Wire-side demand guard: the decoder accepts a `Res` of any arity
    /// and any bit pattern, so every demand-carrying request is checked
    /// against the cluster's dimensionality and for finite, non-negative,
    /// non-zero components before it can reach the solver (a mismatched
    /// arity would trip `debug_assert`s or silently truncate `zip`s; a
    /// zero demand would unbound container counts).  Returns the typed
    /// refusal to send, or `None` when the demand is usable.
    fn check_demand(&self, d: &Res, code: ErrorCode) -> Option<Response> {
        let m = self.slaves.first().map(|s| s.capacity().m()).unwrap_or(0);
        if d.m() != m {
            return Some(err(
                code,
                format!("demand has {} resource types, cluster uses {m}", d.m()),
            ));
        }
        if !d.0.iter().all(|x| x.is_finite() && *x >= 0.0) {
            return Some(err(code, "demand components must be finite and non-negative"));
        }
        if d.is_zero() {
            return Some(err(code, "demand must be non-zero"));
        }
        None
    }

    /// Observable state snapshot (the [`Request::QueryState`] payload and
    /// the unit of transport-parity comparison): aggregates plus one row
    /// per app, optionally filtered.  Deliberately free of anything that
    /// differs across processes (paths, wall clocks).
    pub fn state_view(&self, filter: Option<AppId>) -> StateView {
        StateView {
            clock: self.clock,
            epoch: self.epoch,
            alive_servers: self.lease.n_alive() as u32,
            total_servers: self.slaves.len() as u32,
            active_apps: self.active_apps() as u32,
            total_adjustments: self.total_adjustments,
            total_recoveries: self.total_recoveries,
            utilization: self.utilization(),
            apps: self
                .apps
                .values()
                .filter(|a| filter.map_or(true, |id| a.id == id))
                .map(|a| AppView {
                    id: a.id,
                    state: a.state,
                    containers: self.containers_of(a.id),
                    steps_done: a.steps_done,
                    ckpt_step: a.ckpt_step,
                    adjustments: a.adjustments,
                    recoveries: a.recoveries,
                })
                .collect(),
        }
    }

    /// §III-B: submit the 6-tuple. Returns the assigned id; triggers an
    /// allocation round.
    pub fn submit(&mut self, spec: AppSpec) -> Result<AppId> {
        spec.validate().context("invalid submission")?;
        self.clock += 1;
        self.next_id += 1;
        let id = AppId(self.next_id);
        let model = self.compute.is_some().then(|| spec.cmd[0].clone());
        if let (Some((_, manifest)), Some(m)) = (&self.compute, &model) {
            let meta = manifest.model(m)?;
            if meta.n_params == 0 {
                bail!("model {m} has no parameters");
            }
        }
        self.apps.insert(
            id,
            ManagedApp {
                id,
                spec,
                state: AppState::Pending,
                model,
                trainer: None,
                adjustments: 0,
                recoveries: 0,
                steps_done: 0,
                ckpt_step: 0,
                ckpt_restorable: false,
            },
        );
        self.reallocate()?;
        Ok(id)
    }

    /// Mark an app completed (trainer converged / user cancelled), free its
    /// partition and re-optimize for the survivors.
    pub fn complete(&mut self, id: AppId) -> Result<()> {
        let app = self
            .apps
            .get_mut(&id)
            .ok_or_else(|| anyhow!("unknown app {id}"))?;
        if app.state.is_terminal() {
            bail!("{id} already terminal");
        }
        self.clock += 1;
        app.state = AppState::Completed;
        app.trainer = None;
        for s in &mut self.slaves {
            s.destroy_all(id);
        }
        let _ = self.store.gc(id);
        self.reallocate()?;
        Ok(())
    }

    // ---- liveness (§III-A-2 reports + lease expiry, `crate::fault`) -----

    /// Consume one slave heartbeat, renewing its lease.  `now` is the
    /// caller's clock (the live harness drives time; tests pass anything
    /// monotone).  A real transport would carry the slave's
    /// [`crate::slave::SlaveReport`] payload; liveness needs only the
    /// arrival itself, so none is materialized here.
    pub fn heartbeat(&mut self, server: usize, now: f64) -> Result<()> {
        if server >= self.slaves.len() {
            bail!("unknown server {server}");
        }
        self.lease.renew(server, now);
        Ok(())
    }

    /// The full heartbeat exchange behind [`Request::Heartbeat`]: renew
    /// the lease and, when the slave shipped its [`SlaveReport`],
    /// (a) adopt a changed capacity — the slave is authoritative about
    /// its own hardware, so a differing report is a *capacity event*:
    /// the book is updated, the policy's capacity-derived caches are
    /// dropped ([`CmsPolicy::on_capacity_change`]) and the engine
    /// re-solves; and (b) compute the reconciliation [`Directive`]s that
    /// converge the remote book on the master's (desired-state, so a
    /// lost ack heals on the next beat).  Returns `(alive, directives)`;
    /// a dead server stays dead (late packets must not resurrect it) and
    /// is told to clear every container it still holds.
    pub fn heartbeat_report(
        &mut self,
        server: usize,
        now: f64,
        report: Option<&SlaveReport>,
    ) -> Result<(bool, Vec<Directive>)> {
        if server >= self.slaves.len() {
            bail!("unknown server {server}");
        }
        let alive = self.lease.is_alive(server);
        self.lease.renew(server, now);
        if let Some(r) = report {
            // a capacity is only adoptable if it is sane: right arity,
            // every component finite and non-negative.  NaN would both
            // poison the solve and — since NaN != NaN — re-trigger this
            // event on every beat, so insane reports are ignored loudly.
            let sane = r.capacity.m() == self.slaves[server].capacity().m()
                && r.capacity.0.iter().all(|c| c.is_finite() && *c >= 0.0);
            if !sane {
                log::warn!(
                    "server {server} reports unusable capacity {}; keeping {}",
                    r.capacity,
                    self.slaves[server].capacity()
                );
            }
            if alive && sane && r.capacity != *self.slaves[server].capacity() {
                log::info!(
                    "server {server} reports capacity {} (book had {}); re-solving",
                    r.capacity,
                    self.slaves[server].capacity()
                );
                self.clock += 1;
                self.slaves[server].set_capacity(r.capacity.clone())?;
                self.policy.on_capacity_change();
                self.reallocate()?;
            }
            return Ok((alive, self.reconcile(server, &r.containers)));
        }
        Ok((alive, Vec::new()))
    }

    /// Count a heartbeat's batched [`DirectiveAck`]s (v1.2).  Acks are
    /// telemetry — reconciliation already self-heals lost or failed
    /// directives — so consuming the batch is counters plus a log line
    /// per failure, not bookkeeping the protocol depends on.
    fn note_acks(&mut self, server: usize, acks: &[DirectiveAck]) {
        for a in acks {
            if a.applied {
                self.directive_acks += 1;
            } else {
                self.directive_nacks += 1;
                log::warn!(
                    "server {server} failed to apply {:?} directive for {}; \
                     reconciliation will re-issue",
                    a.kind,
                    a.app
                );
            }
        }
    }

    /// [`Request::Register`]: a slave joins by name instead of a
    /// preassigned `--index` ordinate.
    ///
    /// * Name already in the book: re-join.  If that seat is registered
    ///   *and* alive the join is refused ([`ErrorCode::AlreadyRegistered`]
    ///   — a duplicate slave process; the live holder keeps the seat); a
    ///   dead seat is recovered first (empty, original capacity), then a
    ///   sane differing `capacity` is adopted as a capacity event.
    /// * Unknown name: the first unregistered seat is renamed to the
    ///   joiner and adopts its capacity (validated like any wire-side
    ///   demand: right arity, finite, non-negative, non-zero).
    /// * Every seat registered: the cluster is full
    ///   ([`ErrorCode::InvalidState`]).
    fn register(&mut self, name: &str, capacity: Res) -> Response {
        if let Some(j) = self.slaves.iter().position(|s| s.name == name) {
            if self.registered[j] && self.lease.is_alive(j) {
                return err(
                    ErrorCode::AlreadyRegistered,
                    format!("{name} is already registered as server {j} and alive"),
                );
            }
            // re-join: a crashed-and-restarted slave reclaims its seat
            if !self.lease.is_alive(j) {
                if let Err(e) = self.recover_server(j) {
                    return err(ErrorCode::Internal, e);
                }
            }
            if capacity != *self.slaves[j].capacity() {
                if let Some(rsp) = self.check_demand(&capacity, ErrorCode::InvalidArgument) {
                    return rsp;
                }
                if let Err(e) = self.slaves[j].set_capacity(capacity) {
                    return err(ErrorCode::InvalidState, e);
                }
                self.clock += 1;
                self.policy.on_capacity_change();
                if let Err(e) = self.reallocate() {
                    return err(ErrorCode::Internal, e);
                }
            }
            self.registered[j] = true;
            return Response::Registered { server: j as u32 };
        }
        let Some(j) = (0..self.slaves.len()).find(|&j| !self.registered[j]) else {
            return err(
                ErrorCode::InvalidState,
                format!("cluster full: all {} seats registered", self.slaves.len()),
            );
        };
        if let Some(rsp) = self.check_demand(&capacity, ErrorCode::InvalidArgument) {
            return rsp;
        }
        self.slaves[j].name = name.to_string();
        let adopt = capacity != *self.slaves[j].capacity();
        if adopt {
            if let Err(e) = self.slaves[j].set_capacity(capacity) {
                return err(ErrorCode::InvalidState, e);
            }
        }
        self.registered[j] = true;
        if !self.lease.is_alive(j) {
            if let Err(e) = self.recover_server(j) {
                return err(ErrorCode::Internal, e);
            }
        } else {
            self.lease.renew(j, self.lease.latest_renewal());
        }
        if adopt {
            self.clock += 1;
            self.policy.on_capacity_change();
            if let Err(e) = self.reallocate() {
                return err(ErrorCode::Internal, e);
            }
        }
        Response::Registered { server: j as u32 }
    }

    /// Diff the master's book for `server` against a remote slave's
    /// reported xᵢⱼ column; the directives transform the remote book
    /// into the master's.  Pure function of current state — idempotent,
    /// and an empty vector means the slave is converged.
    /// All destroys come before all creates — a create may depend on
    /// capacity a destroy in the same ack frees, and the agent applies
    /// the list in order against its all-or-nothing local book.
    fn reconcile(&self, server: usize, remote: &BTreeMap<AppId, u32>) -> Vec<Directive> {
        let desired = self.slaves[server].inventory();
        let mut out = Vec::new();
        let mut creates = Vec::new();
        for id in remote.keys() {
            if !desired.contains_key(id) {
                out.push(Directive::DestroyAll { app: *id });
            }
        }
        for (id, want) in &desired {
            let have = remote.get(id).copied().unwrap_or(0);
            if *want > have {
                let Some(app) = self.apps.get(id) else {
                    log::warn!("book holds containers for unmanaged {id}; skipping create");
                    continue;
                };
                creates.push(Directive::Create {
                    app: *id,
                    demand: app.spec.demand.clone(),
                    count: *want - have,
                });
            } else if have > *want {
                out.push(Directive::Destroy { app: *id, count: have - *want });
            }
        }
        out.extend(creates);
        out
    }

    /// Declare every server whose lease lapsed before `now` dead (capacity
    /// and containers reclaimed, affected apps degraded + re-solved).
    /// The whole batch dies before the single re-solve — a rack outage
    /// must not bounce apps through a server that is about to expire in
    /// the same sweep.  Returns the newly dead servers.
    pub fn expire_leases(&mut self, now: f64) -> Result<Vec<usize>> {
        let dead = self.lease.expired(now);
        if !dead.is_empty() {
            self.fail_servers(&dead)?;
        }
        Ok(dead)
    }

    /// Failure injection / forced expiry: server `j` is dead.  Its
    /// capacity leaves the optimization, every partition it hosted is
    /// reclaimed (BSP cannot continue with lost workers), affected apps
    /// roll back to their latest checkpoint and become `Degraded`, and the
    /// allocation engine re-solves on the shrunken cluster (re-placed apps
    /// resume immediately).  Idempotent.  Returns the affected apps.
    pub fn fail_server(&mut self, j: usize) -> Result<Vec<AppId>> {
        if j >= self.slaves.len() {
            bail!("unknown server {j}");
        }
        self.fail_servers(&[j])
    }

    /// Batch kill: every listed (alive) server is marked dead and every
    /// affected partition torn down *before* the one re-solve.
    fn fail_servers(&mut self, servers: &[usize]) -> Result<Vec<AppId>> {
        // (app, first dead server observed hosting it), insertion-ordered
        let mut victims: Vec<(AppId, usize)> = Vec::new();
        let mut died: Vec<usize> = Vec::new();
        for &j in servers {
            if !self.lease.is_alive(j) {
                continue;
            }
            self.lease.mark_dead(j);
            died.push(j);
            for id in self.slaves[j].inventory().keys() {
                if !victims.iter().any(|&(v, _)| v == *id) {
                    victims.push((*id, j));
                }
            }
        }
        if died.is_empty() {
            return Ok(Vec::new());
        }
        self.clock += 1;
        let now = self.clock as f64;
        for &(id, j) in &victims {
            for s in &mut self.slaves {
                s.destroy_all(id);
            }
            // roll back to the newest snapshot that can actually be
            // restored: ckpt_step is only a cursor — if the latest file
            // is corrupt on disk, the store's digest check falls back to
            // the previous good one, and lost work must say so
            let app = self.apps.get_mut(&id).expect("victim is managed");
            let (good_step, restorable) = match self.store.load_latest(id) {
                Ok(Some(c)) => (c.step, true),
                Ok(None) => (0, false),
                // store unreadable: recovery will restart from step 0
                // (restorable = false ⇒ Trainer::new), so the accounting
                // must charge the whole run as lost to match
                Err(e) => {
                    log::warn!(
                        "checkpoint store unreadable for {id}: {e:#}; \
                         treating the whole run as lost"
                    );
                    (0, false)
                }
            };
            let lost = app.steps_done.saturating_sub(good_step);
            app.steps_done = good_step;
            app.ckpt_step = good_step;
            app.ckpt_restorable = restorable;
            app.trainer = None;
            app.state = AppState::Degraded;
            self.recovery_log.failed(id, j, now, lost as f64);
        }
        // feed the MTBF estimator (risk-aware policies; no-op default),
        // then drop the policy's cached solve state — it was derived from
        // the old capacity vector.  Both backends keep this exact order
        // (failure observations, then one invalidation, then one re-solve
        // for the whole batch — tests/fault.rs pins the parity).
        for &j in &died {
            self.policy.on_server_failed(ServerId(j), now);
        }
        self.policy.on_capacity_change();
        self.reallocate()?;
        Ok(victims.into_iter().map(|(id, _)| id).collect())
    }

    /// The server rejoined (empty, original capacity); re-optimize so apps
    /// can grow back.  Idempotent.  The fresh lease is anchored at the
    /// newest heartbeat seen anywhere — harnesses that drive real
    /// wall-clock lease expiry should prefer [`Self::recover_server_at`],
    /// which takes the caller's clock (after a *full* outage there is no
    /// alive lease left to borrow a timestamp from).
    pub fn recover_server(&mut self, j: usize) -> Result<()> {
        let now = self.lease.latest_renewal();
        self.recover_server_at(j, now)
    }

    /// As [`Self::recover_server`], anchoring the fresh lease at `now` in
    /// the caller's clock domain (the same one `heartbeat`/`expire_leases`
    /// use), so the rejoined server is not instantly re-expired.
    pub fn recover_server_at(&mut self, j: usize, now: f64) -> Result<()> {
        if j >= self.slaves.len() {
            bail!("unknown server {j}");
        }
        if self.lease.is_alive(j) {
            return Ok(());
        }
        self.clock += 1;
        self.lease.mark_alive(j, now);
        // repair observation in the master's event-counter clock (the same
        // "now" the failure observation used), then the usual invalidate +
        // re-solve — mirroring the DES ServerRecover arm
        self.policy.on_server_recovered(ServerId(j), self.clock as f64);
        self.policy.on_capacity_change();
        self.reallocate()?;
        Ok(())
    }

    pub fn is_server_alive(&self, j: usize) -> bool {
        self.lease.is_alive(j)
    }

    pub fn alive_servers(&self) -> usize {
        self.lease.n_alive()
    }

    /// Failure → recovery accounting (lost steps, resume scales).
    /// Timestamps are master event ticks (see the `clock` field): a
    /// recovery completed within the same event as the failure reads
    /// `resumed_at == failed_at`; a delayed one shows the events elapsed.
    pub fn recovery_log(&self) -> &RecoveryLog {
        &self.recovery_log
    }

    // ---- progress + checkpoint bookkeeping ------------------------------

    /// Count `steps` BSP steps of progress on a running app — the
    /// bookkeeping path for masters without a compute service (the DES
    /// cross-checks and the fault tests drive this).
    pub fn advance_steps(&mut self, id: AppId, steps: u64) -> Result<()> {
        let app = self
            .apps
            .get_mut(&id)
            .ok_or_else(|| anyhow!("unknown app {id}"))?;
        if app.state != AppState::Running {
            bail!("{id} is {:?}, not Running", app.state);
        }
        if app.trainer.is_some() {
            bail!("{id} has a trainer; steps advance through train_round");
        }
        app.steps_done += steps;
        Ok(())
    }

    pub fn steps_of(&self, id: AppId) -> u64 {
        self.apps.get(&id).map(|a| a.steps_done).unwrap_or(0)
    }

    /// Persist a checkpoint for one running app without killing it
    /// (periodic checkpointing; caps what a server death can cost).
    pub fn checkpoint_app(&mut self, id: AppId) -> Result<()> {
        let app = self
            .apps
            .get_mut(&id)
            .ok_or_else(|| anyhow!("unknown app {id}"))?;
        if app.state != AppState::Running {
            bail!("{id} is {:?}, not Running", app.state);
        }
        save_checkpoint(&self.store, self.ckpt_retain, app)
    }

    /// [`Self::checkpoint_app`] for every running app; returns how many
    /// were checkpointed.
    pub fn checkpoint_all(&mut self) -> Result<usize> {
        let ids: Vec<AppId> = self
            .apps
            .iter()
            .filter(|(_, a)| a.state == AppState::Running)
            .map(|(id, _)| *id)
            .collect();
        for id in &ids {
            self.checkpoint_app(*id)?;
        }
        Ok(ids.len())
    }

    /// Containers currently held by `id` across all slaves.
    pub fn containers_of(&self, id: AppId) -> u32 {
        self.slaves.iter().map(|s| s.count_for(id)).sum()
    }

    /// Incremental-path telemetry of the scheduling policy (cache hits,
    /// warm starts, delta packs, skipped admission prefixes) — `None` for
    /// stateless baseline policies.  DESIGN.md §10.
    pub fn scheduler_stats(&self) -> Option<crate::sched::EngineStats> {
        self.policy.engine_stats()
    }

    /// Current xᵢⱼ row for `id`.
    fn placement_of(&self, id: AppId) -> BTreeMap<ServerId, u32> {
        self.slaves
            .iter()
            .enumerate()
            .filter_map(|(j, s)| {
                let c = s.count_for(id);
                (c > 0).then_some((ServerId(j), c))
            })
            .collect()
    }

    /// Eq. 1 over the slaves' double-entry books (dead servers' capacity
    /// has left the cluster).  One pass against the lease table's whole
    /// liveness column rather than a per-server probe.
    pub fn utilization(&self) -> f64 {
        let m = self.slaves.first().map(|s| s.capacity().m()).unwrap_or(0);
        let (used, cap) = self
            .slaves
            .iter()
            .zip(self.lease.alive_mask())
            .filter(|(_, &alive)| alive)
            .fold((Res::zeros(m), Res::zeros(m)), |(mut u, mut c), (s, _)| {
                u += &s.used();
                c += s.capacity();
                (u, c)
            });
        used.utilization_sum(&cap)
    }

    /// Snapshot the cluster, ask the policy, enforce the update (§III-C).
    /// The snapshot/decide/enforce split is what lets the DES and the live
    /// master share every policy: this method is the live counterpart of
    /// the simulator's event handler.
    pub fn reallocate(&mut self) -> Result<()> {
        // a dead server contributes zero capacity but keeps its ServerId
        // ordinate, so placements elsewhere stay stable.  One sweep over
        // the liveness column builds the whole vector — a lease-expiry
        // batch that killed servers in several cells feeds every cell
        // through this single snapshot/dispatch.
        let capacities: Vec<Res> = self
            .slaves
            .iter()
            .zip(self.lease.alive_mask())
            .map(|(s, &alive)| {
                if alive {
                    s.capacity().clone()
                } else {
                    Res::zeros(s.capacity().m())
                }
            })
            .collect();

        let mut snapshot: BTreeMap<AppId, SchedApp> = BTreeMap::new();
        for app in self.apps.values() {
            if app.state.is_terminal() {
                continue;
            }
            let placement = self.placement_of(app.id);
            snapshot.insert(
                app.id,
                SchedApp {
                    id: app.id,
                    demand: app.spec.demand.clone(),
                    weight: app.spec.weight as f64,
                    n_min: app.spec.n_min,
                    n_max: app.spec.n_max,
                    containers: placement.values().sum(),
                    placement,
                    // ids are assigned in submission order, so they double
                    // as the FIFO key (the DES uses simulated hours)
                    submit: app.id.0 as f64,
                    // static policies run the app at its requested width
                    baseline_n: app.spec.n_max,
                    engine: app.spec.executor,
                },
            );
        }

        let update = {
            let ctx = SchedCtx {
                now: self.clock as f64,
                apps: &snapshot,
                capacities: &capacities,
            };
            self.policy.on_change(&ctx)
        };
        let Some(update) = update else {
            log::warn!("no feasible allocation; keeping existing partitions");
            return Ok(());
        };

        self.enforce(update)
    }

    /// Fig. 5 steps (3)–(4): destroy/create containers, checkpoint + kill +
    /// resume the adjusted apps, start the newly admitted ones, restore the
    /// degraded ones from their checkpoints.
    fn enforce(&mut self, update: AllocationUpdate) -> Result<()> {
        let adjusted: Vec<AppId> = update.adjusted.clone();

        // (a) checkpoint + kill adjusted apps before touching containers
        let mut killed = 0u32;
        for id in &adjusted {
            let Some(app) = self.apps.get_mut(id) else {
                log::warn!("policy adjusted unknown {id}; ignoring");
                continue;
            };
            if app.state == AppState::Degraded {
                continue; // already down from a failure; nothing to save
            }
            app.state = AppState::Checkpointing;
            save_checkpoint(&self.store, self.ckpt_retain, app)?;
            app.trainer = None;
            app.state = AppState::Killed;
            app.adjustments += 1;
            killed += 1;
        }
        // only apps that actually went through checkpoint+kill count
        // toward Eq. 4 — skipped (degraded/unknown) entries did not adjust
        self.total_adjustments += killed;

        // (b) diff the target assignment against the slaves' books:
        // all destroys first (shrinkers free the space), then all creates
        let active: Vec<AppId> = self
            .apps
            .iter()
            .filter(|(_, a)| !a.state.is_terminal())
            .map(|(id, _)| *id)
            .collect();
        let mut creates: Vec<(AppId, BTreeMap<ServerId, u32>)> = Vec::new();
        for id in &active {
            let target = update.assignment.get(id).cloned().unwrap_or_default();
            let current = self.placement_of(*id);
            if target == current {
                continue;
            }
            for (sid, cnt) in &current {
                self.slaves[sid.0].destroy(*id, *cnt)?;
            }
            creates.push((*id, target));
        }
        for (id, target) in &creates {
            let demand = self.apps[id].spec.demand.clone();
            for (sid, cnt) in target {
                self.slaves[sid.0].create(*id, &demand, *cnt)?;
            }
        }

        // (c) resume adjusted, restore degraded, start newly admitted apps
        let now = self.clock as f64;
        let ids: Vec<AppId> = self.apps.keys().copied().collect();
        for id in ids {
            let held = self.containers_of(id);
            let app = self.apps.get_mut(&id).unwrap();
            if app.state.is_terminal() {
                continue;
            }
            match app.state {
                AppState::Killed if held > 0 => {
                    // resume from checkpoint at the new width
                    if let (Some((h, manifest)), Some(model)) = (&self.compute, &app.model) {
                        let meta = manifest.model(model)?;
                        let cfg = TrainerConfig {
                            workers: held,
                            ..TrainerConfig::default()
                        };
                        app.state = AppState::Resuming;
                        app.trainer = Some(
                            Trainer::resume(id, meta, h.clone(), cfg, &self.store)
                                .context("resume")?,
                        );
                    }
                    app.state = AppState::Running;
                }
                AppState::Degraded if held > 0 => {
                    // failure recovery: restore from the latest checkpoint
                    // at the newly solved scale
                    app.state = AppState::Recovering;
                    if let (Some((h, manifest)), Some(model)) = (&self.compute, &app.model) {
                        let meta = manifest.model(model)?;
                        let cfg = TrainerConfig {
                            workers: held,
                            ..TrainerConfig::default()
                        };
                        // fail_servers probed the store once; don't re-read
                        let trainer = if app.ckpt_restorable {
                            Trainer::resume(id, meta, h.clone(), cfg, &self.store)
                                .context("recover")?
                        } else {
                            // never checkpointed: restart from step 0 (the
                            // lost work was the whole run, already logged)
                            Trainer::new(id, meta, h.clone(), cfg)
                                .context("restart after failure")?
                        };
                        app.steps_done = trainer.current_step();
                        app.ckpt_step = app.steps_done;
                        app.trainer = Some(trainer);
                    }
                    app.state = AppState::Running;
                    app.recoveries += 1;
                    self.total_recoveries += 1;
                    self.recovery_log.resumed(id, now, held);
                }
                AppState::Pending if held > 0 => {
                    if let (Some((h, manifest)), Some(model)) = (&self.compute, &app.model) {
                        let meta = manifest.model(model)?;
                        let cfg = TrainerConfig {
                            workers: held,
                            ..TrainerConfig::default()
                        };
                        app.trainer = Some(
                            Trainer::new(id, meta, h.clone(), cfg).context("start")?,
                        );
                    }
                    app.state = AppState::Running;
                }
                _ => {}
            }
        }
        Ok(())
    }

    /// Drive every running trainer `steps` BSP steps (time-shared on this
    /// 1-core image). Returns (app, step, loss) logs.
    pub fn train_round(&mut self, steps: u64) -> Result<Vec<(AppId, u64, f32)>> {
        let mut out = Vec::new();
        for app in self.apps.values_mut() {
            if let Some(t) = &mut app.trainer {
                let log = t.run(steps)?;
                app.steps_done = log.step;
                out.push((app.id, log.step, log.loss));
            }
        }
        Ok(out)
    }

    pub fn app_state(&self, id: AppId) -> Option<AppState> {
        self.apps.get(&id).map(|a| a.state)
    }

    pub fn app(&self, id: AppId) -> Option<&ManagedApp> {
        self.apps.get(&id)
    }

    pub fn store(&self) -> &CheckpointStore {
        &self.store
    }

    /// Active (non-terminal) app count.
    pub fn active_apps(&self) -> usize {
        self.apps.values().filter(|a| !a.state.is_terminal()).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::app::Engine;

    fn store(tag: &str) -> CheckpointStore {
        let d = std::env::temp_dir().join(format!("dorm_master_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        CheckpointStore::new(d).unwrap()
    }

    fn spec(cpu: f64, gpu: f64, ram: f64, w: u32, lo: u32, hi: u32) -> AppSpec {
        AppSpec {
            executor: Engine::MxNet,
            demand: Res::cpu_gpu_ram(cpu, gpu, ram),
            weight: w,
            n_max: hi,
            n_min: lo,
            cmd: ["lr".into(), "lr".into()],
        }
    }

    fn master(tag: &str) -> DormMaster {
        DormMaster::new(
            &ClusterConfig::uniform(4, Res::cpu_gpu_ram(12.0, 0.0, 64.0)),
            DormConfig { theta1: 0.5, theta2: 0.5 },
            store(tag),
        )
    }

    #[test]
    fn lone_app_gets_max_partition() {
        let mut m = master("lone");
        let id = m.submit(spec(2.0, 0.0, 8.0, 1, 1, 12)).unwrap();
        assert_eq!(m.app_state(id), Some(AppState::Running));
        assert_eq!(m.containers_of(id), 12);
        assert!(m.utilization() > 0.0);
        // the live master runs the same incremental engine as the DES
        let stats = m.scheduler_stats().expect("Dorm policy has an engine");
        assert!(stats.solves >= 1);
        assert!(stats.delta_packs >= 1, "{stats:?}");
    }

    #[test]
    fn second_submission_shrinks_first() {
        let mut m = master("shrink");
        let a = m.submit(spec(2.0, 0.0, 8.0, 1, 1, 24)).unwrap();
        assert_eq!(m.containers_of(a), 24); // all 48 CPUs
        let b = m.submit(spec(2.0, 0.0, 8.0, 1, 1, 24)).unwrap();
        // capacity: 48 CPUs -> 24 containers split between the two
        let (ca, cb) = (m.containers_of(a), m.containers_of(b));
        assert!(ca + cb <= 24);
        assert!(cb >= 1, "newcomer must be admitted");
        assert!(m.total_adjustments >= 1, "first app was adjusted");
        assert_eq!(m.app_state(a), Some(AppState::Running));
        assert_eq!(m.app_state(b), Some(AppState::Running));
    }

    #[test]
    fn completion_releases_and_regrows() {
        let mut m = master("release");
        let a = m.submit(spec(2.0, 0.0, 8.0, 1, 1, 24)).unwrap();
        let b = m.submit(spec(2.0, 0.0, 8.0, 1, 1, 24)).unwrap();
        m.complete(a).unwrap();
        assert_eq!(m.app_state(a), Some(AppState::Completed));
        assert_eq!(m.containers_of(a), 0);
        // survivor takes the freed capacity (within θ₂ limits: 1 app -> 1 adjustment allowed)
        assert!(m.containers_of(b) > 12, "{}", m.containers_of(b));
        assert!(m.complete(a).is_err(), "double completion rejected");
    }

    #[test]
    fn invalid_submissions_rejected() {
        let mut m = master("invalid");
        assert!(m.submit(spec(2.0, 0.0, 8.0, 1, 0, 4)).is_err()); // n_min 0
        assert!(m.submit(spec(2.0, 0.0, 8.0, 0, 1, 4)).is_err()); // weight 0
        assert_eq!(m.active_apps(), 0);
    }

    #[test]
    fn coalesced_heartbeats_one_resolve_per_batch() {
        let mut m = master("coalesce");
        m.submit(spec(2.0, 0.0, 8.0, 1, 1, 12)).unwrap();
        let solves_before = m.scheduler_stats().unwrap().solves;
        // converged reports for all four servers, two of them carrying a
        // capacity event: the batch must adopt both through one solve
        let beats: Vec<Request> = (0..4usize)
            .map(|j| {
                let mut report = m.slaves[j].report();
                if j >= 2 {
                    report.capacity = Res::cpu_gpu_ram(16.0, 0.0, 64.0);
                }
                Request::Heartbeat {
                    server: j as u32,
                    now_hours: 1.0,
                    report: Some(report),
                    acks: vec![],
                }
            })
            .collect();
        let rsps = m.dispatch_heartbeats(beats);
        assert_eq!(rsps.len(), 4);
        for r in &rsps {
            assert!(matches!(r, Response::HeartbeatAck { alive: true, .. }), "{r:?}");
        }
        assert_eq!(*m.slaves[2].capacity(), Res::cpu_gpu_ram(16.0, 0.0, 64.0));
        assert_eq!(*m.slaves[3].capacity(), Res::cpu_gpu_ram(16.0, 0.0, 64.0));
        let solves_after = m.scheduler_stats().unwrap().solves;
        assert_eq!(solves_after, solves_before + 1, "two capacity events, one solve");

        // per-beat validation stays typed inside a batch
        let rsps = m.dispatch_heartbeats(vec![
            Request::Heartbeat { server: 99, now_hours: 1.1, report: None, acks: vec![] },
            Request::Heartbeat { server: 0, now_hours: f64::NAN, report: None, acks: vec![] },
            Request::Heartbeat { server: 0, now_hours: 1.1, report: None, acks: vec![] },
        ]);
        assert!(
            matches!(&rsps[0], Response::Error(e) if e.code == ErrorCode::UnknownServer),
            "{:?}",
            rsps[0]
        );
        assert!(
            matches!(&rsps[1], Response::Error(e) if e.code == ErrorCode::InvalidArgument),
            "{:?}",
            rsps[1]
        );
        assert!(
            matches!(&rsps[2], Response::HeartbeatAck { alive: true, .. }),
            "{:?}",
            rsps[2]
        );
    }

    #[test]
    fn oversized_floor_defers_app() {
        let mut m = master("defer");
        // demands exceed the whole cluster -> stays pending
        let id = m.submit(spec(50.0, 0.0, 8.0, 1, 1, 2)).unwrap();
        assert_eq!(m.app_state(id), Some(AppState::Pending));
        assert_eq!(m.containers_of(id), 0);
    }

    #[test]
    fn static_baseline_drives_live_master() {
        use crate::baselines::StaticPolicy;
        let cluster = ClusterConfig::uniform(4, Res::cpu_gpu_ram(12.0, 0.0, 64.0));
        let mut m = DormMaster::with_policy(
            &cluster,
            Box::new(StaticPolicy::new()),
            store("static"),
        );
        // the Swarm baseline gives each app its fixed width and never
        // resizes — now running against the real control plane
        let a = m.submit(spec(2.0, 0.0, 8.0, 1, 1, 8)).unwrap();
        assert_eq!(m.containers_of(a), 8);
        let b = m.submit(spec(2.0, 0.0, 8.0, 1, 1, 8)).unwrap();
        assert_eq!(m.containers_of(a), 8, "static never resizes");
        assert_eq!(m.containers_of(b), 8);
        assert_eq!(m.total_adjustments, 0);
        // an app whose full fixed partition does not fit waits pending
        let c = m.submit(spec(2.0, 0.0, 8.0, 1, 1, 16)).unwrap();
        assert_eq!(m.app_state(c), Some(AppState::Pending));
        assert_eq!(m.containers_of(c), 0);
        // completion frees space; the queued app starts at full width
        m.complete(a).unwrap();
        assert_eq!(m.containers_of(c), 16);
        assert_eq!(m.app_state(c), Some(AppState::Running));
        assert_eq!(m.total_adjustments, 0, "static adjusted nothing");
    }

    #[test]
    fn dorm_master_reuses_engine_cache_on_identical_snapshots() {
        let mut m = master("cache");
        let id = m.submit(spec(2.0, 0.0, 8.0, 1, 1, 12)).unwrap();
        let held = m.containers_of(id);
        // no state change between explicit re-solves: snapshot identical,
        // so the engine must answer from its cache and change nothing
        m.reallocate().unwrap();
        m.reallocate().unwrap();
        assert_eq!(m.containers_of(id), held);
        assert_eq!(m.total_adjustments, 0);
    }

    #[test]
    fn slave_books_match_master_utilization() {
        let mut m = master("books");
        let _ = m.submit(spec(3.0, 0.0, 16.0, 1, 1, 8)).unwrap();
        let _ = m.submit(spec(2.0, 0.0, 8.0, 2, 1, 8)).unwrap();
        // every slave within capacity
        for s in &m.slaves {
            assert!(s.used().fits_in(s.capacity()), "{}", s.name);
        }
        assert!(m.utilization() > 0.0 && m.utilization() <= 3.0);
    }

    #[test]
    fn server_death_degrades_and_recovers_affected_apps() {
        let mut m = master("fail");
        let a = m.submit(spec(2.0, 0.0, 8.0, 1, 1, 24)).unwrap();
        assert_eq!(m.containers_of(a), 24, "spans all 4 servers");
        m.advance_steps(a, 100).unwrap();
        // checkpoint, then make 40 more steps of progress past it
        m.checkpoint_app(a).unwrap();
        m.advance_steps(a, 40).unwrap();
        let victims = m.fail_server(0).unwrap();
        assert_eq!(victims, vec![a]);
        assert!(!m.is_server_alive(0));
        assert_eq!(m.alive_servers(), 3);
        // re-solved on the 3 remaining servers: running again, smaller
        assert_eq!(m.app_state(a), Some(AppState::Running));
        let held = m.containers_of(a);
        assert!(held > 0 && held <= 18, "held {held}");
        assert_eq!(m.slaves[0].count_for(a), 0, "nothing on the dead server");
        // lost work = steps since the checkpoint; progress rolled back
        assert_eq!(m.steps_of(a), 100);
        let rec = &m.recovery_log().records()[0];
        assert_eq!(rec.lost_work, 40.0);
        assert_eq!(rec.resumed_scale, held);
        assert!(rec.resumed_at.is_some());
        assert_eq!(m.total_recoveries, 1);
        assert_eq!(m.app(a).unwrap().recoveries, 1);
        // the latest checkpoint is what recovery resumed from
        let ckpt = m.store().load_latest(a).unwrap().unwrap();
        assert_eq!(ckpt.step, 100);
        // double kill is a no-op
        assert!(m.fail_server(0).unwrap().is_empty());
        // recovery of the server lets the app grow back
        m.recover_server(0).unwrap();
        assert_eq!(m.alive_servers(), 4);
        assert!(m.containers_of(a) >= held);
    }

    #[test]
    fn missed_heartbeats_expire_the_lease() {
        let cluster = ClusterConfig::uniform(3, Res::cpu_gpu_ram(8.0, 0.0, 32.0));
        let mut m = DormMaster::new(
            &cluster,
            DormConfig { theta1: 0.5, theta2: 0.5 },
            store("lease"),
        )
        .with_fault(&FaultConfig { lease_timeout_hours: 1.0, ..Default::default() });
        let a = m.submit(spec(2.0, 0.0, 8.0, 1, 1, 12)).unwrap();
        assert_eq!(m.containers_of(a), 12, "spans all 3 servers");
        // servers 1 and 2 report at t=2; server 0 has gone silent
        m.heartbeat(1, 2.0).unwrap();
        m.heartbeat(2, 2.0).unwrap();
        let dead = m.expire_leases(2.5).unwrap();
        assert_eq!(dead, vec![0]);
        assert_eq!(m.alive_servers(), 2);
        assert_eq!(m.app_state(a), Some(AppState::Running), "recovered");
        assert!(m.containers_of(a) <= 8, "re-solved on 2 servers");
        assert_eq!(m.slaves[0].count_for(a), 0);
        // a dead server's late heartbeat does not resurrect it
        m.heartbeat(0, 3.0).unwrap();
        assert!(!m.is_server_alive(0));
    }

    #[test]
    fn unaffected_apps_survive_failures_untouched() {
        use crate::baselines::StaticPolicy;
        let cluster = ClusterConfig::uniform(3, Res::cpu_gpu_ram(16.0, 0.0, 64.0));
        let mut m = DormMaster::with_policy(
            &cluster,
            Box::new(StaticPolicy::new()),
            store("bystander"),
        );
        // static places each 8-wide app on one server (16 CPU / 64 GB fit)
        let a = m.submit(spec(2.0, 0.0, 8.0, 1, 1, 8)).unwrap();
        let b = m.submit(spec(2.0, 0.0, 8.0, 1, 1, 8)).unwrap();
        let sa = m.placement_of(a).keys().next().unwrap().0;
        let sb = m.placement_of(b).keys().next().unwrap().0;
        assert_ne!(sa, sb, "static packs one app per server here");
        m.fail_server(sa).unwrap();
        assert_eq!(m.containers_of(b), 8, "bystander untouched");
        assert_eq!(m.app(b).unwrap().recoveries, 0);
        // the victim re-placed at its fixed width on a surviving server
        assert_eq!(m.containers_of(a), 8);
        assert_eq!(m.app(a).unwrap().recoveries, 1);
        assert_eq!(m.total_adjustments, 0, "recovery is not an adjustment");
    }

    #[test]
    fn rack_outage_expires_as_one_batch() {
        // 3 servers, app spans all; servers 0 AND 1 go silent together:
        // batch expiry must not bounce the app through server 1 (which
        // would show up as a spurious second recovery cycle)
        let cluster = ClusterConfig::uniform(3, Res::cpu_gpu_ram(8.0, 0.0, 32.0));
        let mut m = DormMaster::new(
            &cluster,
            DormConfig { theta1: 0.5, theta2: 0.5 },
            store("rack"),
        )
        .with_fault(&FaultConfig { lease_timeout_hours: 1.0, ..Default::default() });
        let a = m.submit(spec(2.0, 0.0, 8.0, 1, 1, 12)).unwrap();
        assert_eq!(m.containers_of(a), 12, "spans all 3 servers");
        m.heartbeat(2, 2.0).unwrap();
        let dead = m.expire_leases(2.5).unwrap();
        assert_eq!(dead, vec![0, 1]);
        assert_eq!(m.alive_servers(), 1);
        assert_eq!(m.app(a).unwrap().recoveries, 1, "exactly one recovery cycle");
        assert_eq!(m.recovery_log().len(), 1);
        assert_eq!(m.containers_of(a), 4, "re-solved on the lone survivor");
        assert_eq!(m.slaves[0].count_for(a), 0);
        assert_eq!(m.slaves[1].count_for(a), 0);
    }

    #[test]
    fn full_outage_recovery_uses_callers_clock() {
        let cluster = ClusterConfig::uniform(2, Res::cpu_gpu_ram(8.0, 0.0, 32.0));
        let mut m = DormMaster::new(
            &cluster,
            DormConfig { theta1: 0.5, theta2: 0.5 },
            store("outage"),
        )
        .with_fault(&FaultConfig { lease_timeout_hours: 1.0, ..Default::default() });
        let a = m.submit(spec(2.0, 0.0, 8.0, 1, 1, 8)).unwrap();
        let dead = m.expire_leases(5.0).unwrap(); // nobody ever heartbeat
        assert_eq!(dead, vec![0, 1]);
        assert_eq!(m.app_state(a), Some(AppState::Degraded));
        // rejoin at t=5: the lease must anchor at the caller's clock, not
        // a stale renewal, or the next expiry sweep kills it right again
        m.recover_server_at(0, 5.0).unwrap();
        assert_eq!(m.app_state(a), Some(AppState::Running));
        assert!(
            m.expire_leases(5.5).unwrap().is_empty(),
            "freshly rejoined server must stay alive"
        );
        assert!(m.is_server_alive(0));
    }

    #[test]
    fn heartbeat_for_unknown_server_is_a_typed_error() {
        let mut m = master("hb_unknown");
        // the legacy helper refuses instead of silently inserting a lease
        assert!(m.heartbeat(4, 1.0).is_err(), "only servers 0..4 exist");
        assert!(m.heartbeat_report(99, 1.0, None).is_err());
        // ... and the dispatch surface types the refusal
        match m.dispatch(Request::Heartbeat {
            server: 4,
            now_hours: 1.0,
            report: None,
            acks: vec![],
        }) {
            Response::Error(e) => assert_eq!(e.code, ErrorCode::UnknownServer),
            other => panic!("expected a typed error, got {other:?}"),
        }
        assert_eq!(m.alive_servers(), 4, "no lease state was invented");
        // non-finite times are refused before they can poison the table
        match m.dispatch(Request::Heartbeat {
            server: 0,
            now_hours: f64::NAN,
            report: None,
            acks: vec![],
        }) {
            Response::Error(e) => assert_eq!(e.code, ErrorCode::InvalidArgument),
            other => panic!("expected a typed error, got {other:?}"),
        }
    }

    #[test]
    fn dispatch_covers_the_legacy_surface() {
        let mut m = master("dispatch");
        let rsp = m.dispatch(Request::Submit { spec: spec(2.0, 0.0, 8.0, 1, 1, 12) });
        let id = match rsp {
            Response::Submitted { app } => app,
            other => panic!("submit answered {other:?}"),
        };
        assert_eq!(
            m.dispatch(Request::AdvanceSteps { app: id, steps: 7 }),
            Response::Ok
        );
        assert_eq!(m.dispatch(Request::CheckpointApp { app: id }), Response::Ok);
        match m.dispatch(Request::QueryState { app: Some(id) }) {
            Response::State(v) => {
                assert_eq!(v.apps.len(), 1);
                assert_eq!(v.apps[0].containers, 12);
                assert_eq!(v.apps[0].steps_done, 7);
                assert_eq!(v.apps[0].ckpt_step, 7);
                assert_eq!(v.active_apps, 1);
            }
            other => panic!("query answered {other:?}"),
        }
        match m.dispatch(Request::FailServer { server: 0 }) {
            Response::Affected { apps } => assert_eq!(apps, vec![id]),
            other => panic!("fail answered {other:?}"),
        }
        assert_eq!(
            m.dispatch(Request::RecoverServer { server: 0, now_hours: 1.0 }),
            Response::Ok
        );
        assert_eq!(m.dispatch(Request::Complete { app: id }), Response::Ok);
        match m.dispatch(Request::Complete { app: id }) {
            Response::Error(e) => assert_eq!(e.code, ErrorCode::InvalidState),
            other => panic!("double completion answered {other:?}"),
        }
        match m.dispatch(Request::QueryState { app: Some(AppId(42)) }) {
            Response::Error(e) => assert_eq!(e.code, ErrorCode::UnknownApp),
            other => panic!("bogus query answered {other:?}"),
        }
        // version negotiation lives behind dispatch too
        let hello = m.dispatch(Request::Hello {
            major: proto::PROTO_MAJOR,
            minor: proto::PROTO_MINOR,
        });
        assert!(matches!(hello, Response::HelloAck { .. }));
        match m.dispatch(Request::Hello { major: proto::PROTO_MAJOR + 1, minor: 0 }) {
            Response::Error(e) => assert_eq!(e.code, ErrorCode::VersionMismatch),
            other => panic!("future major answered {other:?}"),
        }
    }

    /// v1.3 retry dedupe: a re-sent `Submit`/`Complete` carrying a seen
    /// retry id gets the cached response and mutates state exactly once —
    /// the double-apply guard a `FailoverTransport` re-dial depends on.
    #[test]
    fn retry_ids_dedupe_resent_mutations() {
        let mut m = master("dedupe");
        let rsp =
            m.dispatch_rid(Request::Submit { spec: spec(2.0, 0.0, 8.0, 1, 1, 8) }, Some(42));
        let id = match rsp {
            Response::Submitted { app } => app,
            other => panic!("submit answered {other:?}"),
        };
        assert_eq!(m.state_view(None).active_apps, 1);
        // the retry: same rid, cached response, still one app
        let again =
            m.dispatch_rid(Request::Submit { spec: spec(2.0, 0.0, 8.0, 1, 1, 8) }, Some(42));
        assert_eq!(again, Response::Submitted { app: id });
        assert_eq!(m.state_view(None).active_apps, 1, "retry must not double-apply");
        // a different rid is a genuinely new submission
        match m.dispatch_rid(Request::Submit { spec: spec(2.0, 0.0, 8.0, 1, 1, 8) }, Some(43)) {
            Response::Submitted { app } => assert_ne!(app, id),
            other => panic!("fresh submit answered {other:?}"),
        }
        assert_eq!(m.state_view(None).active_apps, 2);
        // Complete retried: the cache answers Ok where a raw re-dispatch
        // would answer InvalidState (already terminal)
        assert_eq!(m.dispatch_rid(Request::Complete { app: id }, Some(44)), Response::Ok);
        assert_eq!(
            m.dispatch_rid(Request::Complete { app: id }, Some(44)),
            Response::Ok,
            "retried completion must hit the cache, not InvalidState"
        );
        assert_eq!(m.state_view(None).active_apps, 1);
        // an UNstamped duplicate still sees the raw semantics
        match m.dispatch(Request::Complete { app: id }) {
            Response::Error(e) => assert_eq!(e.code, ErrorCode::InvalidState),
            other => panic!("unstamped duplicate answered {other:?}"),
        }
        // rid ignored on never-stamped kinds: two queries both answer
        match m.dispatch_rid(Request::QueryState { app: None }, Some(45)) {
            Response::State(_) => {}
            other => panic!("query answered {other:?}"),
        }
        // the memory is bounded: old ids fall out after DEDUPE_CAP others
        for k in 0..(DEDUPE_CAP as u64 + 1) {
            let _ = m.dispatch_rid(Request::Complete { app: AppId(9999) }, Some(1000 + k));
        }
        match m.dispatch_rid(Request::Submit { spec: spec(2.0, 0.0, 8.0, 1, 1, 8) }, Some(42)) {
            Response::Submitted { app } => assert_ne!(app, id, "evicted id re-applies"),
            other => panic!("post-eviction submit answered {other:?}"),
        }
    }

    #[test]
    fn capacity_event_heartbeat_invalidates_and_resolves() {
        let mut m = master("capev");
        let id = m.submit(spec(2.0, 0.0, 8.0, 1, 1, 24)).unwrap();
        assert_eq!(m.containers_of(id), 24, "48 CPUs -> 24 containers");
        // server 0 now reports only 6 CPUs: the master must adopt it,
        // drop capacity-derived caches, and re-solve smaller
        let report = SlaveReport {
            name: "slave00".into(),
            capacity: Res::cpu_gpu_ram(6.0, 0.0, 64.0),
            available: Res::cpu_gpu_ram(6.0, 0.0, 64.0),
            containers: Default::default(),
        };
        let (alive, directives) = m.heartbeat_report(0, 1.0, Some(&report)).unwrap();
        assert!(alive);
        assert_eq!(*m.slaves[0].capacity(), Res::cpu_gpu_ram(6.0, 0.0, 64.0));
        // the re-solve happened: the old 24-wide placement (6 per server)
        // no longer fits server 0, and total width obeys the 42-CPU cap
        let held = m.containers_of(id);
        assert!(held < 24 && held >= 1, "re-solved smaller, holds {held}");
        assert!(m.slaves[0].count_for(id) <= 3, "6 CPUs fit at most 3");
        for s in &m.slaves {
            assert!(s.used().fits_in(s.capacity()), "{} over capacity", s.name);
        }
        // the directives converge the (empty) remote book on the new book
        let created: u32 = directives
            .iter()
            .map(|d| match d {
                Directive::Create { count, .. } => *count,
                _ => 0,
            })
            .sum();
        assert_eq!(created, m.slaves[0].count_for(id));
    }

    #[test]
    fn degraded_app_waits_when_nothing_fits() {
        let cluster = ClusterConfig::uniform(2, Res::cpu_gpu_ram(8.0, 0.0, 32.0));
        let mut m = DormMaster::new(
            &cluster,
            DormConfig { theta1: 0.5, theta2: 0.5 },
            store("wait"),
        );
        let a = m.submit(spec(2.0, 0.0, 8.0, 1, 4, 8)).unwrap();
        assert_eq!(m.app_state(a), Some(AppState::Running));
        // kill both servers: nowhere to recover to
        m.fail_server(0).unwrap();
        m.fail_server(1).unwrap();
        assert_eq!(m.app_state(a), Some(AppState::Degraded));
        assert_eq!(m.containers_of(a), 0);
        // capacity returns -> recovery completes
        m.recover_server(0).unwrap();
        assert_eq!(m.app_state(a), Some(AppState::Running));
        assert!(m.containers_of(a) >= 4);
    }

    #[test]
    fn register_seats_slaves_and_refuses_live_duplicates() {
        let mut m = master("register");
        let cap = Res::cpu_gpu_ram(12.0, 0.0, 64.0);
        // a new name takes the first unregistered seat
        let j = match m.dispatch(Request::Register {
            name: "rack1-a".into(),
            capacity: cap.clone(),
        }) {
            Response::Registered { server } => server as usize,
            other => panic!("expected Registered, got {other:?}"),
        };
        assert_eq!(j, 0);
        assert_eq!(m.slaves[0].name, "rack1-a");
        // a second process claiming the same live name is refused
        match m.dispatch(Request::Register { name: "rack1-a".into(), capacity: cap.clone() }) {
            Response::Error(e) => assert_eq!(e.code, ErrorCode::AlreadyRegistered),
            other => panic!("expected AlreadyRegistered, got {other:?}"),
        }
        // distinct names fill distinct seats
        match m.dispatch(Request::Register { name: "rack1-b".into(), capacity: cap.clone() }) {
            Response::Registered { server } => assert_eq!(server, 1),
            other => panic!("expected Registered, got {other:?}"),
        }
        // a dead registered seat can be reclaimed by its own name (restart)
        m.fail_server(0).unwrap();
        match m.dispatch(Request::Register { name: "rack1-a".into(), capacity: cap }) {
            Response::Registered { server } => assert_eq!(server, 0),
            other => panic!("expected rejoin, got {other:?}"),
        }
        assert!(m.is_server_alive(0), "rejoin recovers the dead seat");
    }

    #[test]
    fn register_validates_capacity_and_cluster_bound() {
        let mut m = master("register_bounds");
        // wrong arity refused before it can poison the solver
        match m.dispatch(Request::Register {
            name: "bad".into(),
            capacity: Res(vec![1.0]),
        }) {
            Response::Error(e) => assert_eq!(e.code, ErrorCode::InvalidArgument),
            other => panic!("expected InvalidArgument, got {other:?}"),
        }
        // fill all four seats, then the cluster is full
        for i in 0..4 {
            let rsp = m.dispatch(Request::Register {
                name: format!("s{i}-new"),
                capacity: Res::cpu_gpu_ram(12.0, 0.0, 64.0),
            });
            assert!(matches!(rsp, Response::Registered { .. }), "{rsp:?}");
        }
        match m.dispatch(Request::Register {
            name: "fifth".into(),
            capacity: Res::cpu_gpu_ram(12.0, 0.0, 64.0),
        }) {
            Response::Error(e) => assert_eq!(e.code, ErrorCode::InvalidState),
            other => panic!("expected InvalidState, got {other:?}"),
        }
    }

    #[test]
    fn heartbeat_acks_are_counted_not_depended_on() {
        use crate::proto::AckKind;
        let mut m = master("acks");
        let id = m.submit(spec(2.0, 0.0, 8.0, 1, 1, 4)).unwrap();
        let rsp = m.dispatch(Request::Heartbeat {
            server: 0,
            now_hours: 1.0,
            report: None,
            acks: vec![
                DirectiveAck { app: id, kind: AckKind::Create, applied: true },
                DirectiveAck { app: id, kind: AckKind::Create, applied: true },
                DirectiveAck { app: id, kind: AckKind::Destroy, applied: false },
            ],
        });
        assert!(matches!(rsp, Response::HeartbeatAck { alive: true, .. }), "{rsp:?}");
        assert_eq!(m.directive_acks, 2);
        assert_eq!(m.directive_nacks, 1);
        // the nack changed nothing in the book — reconciliation heals it
        assert_eq!(m.containers_of(id), 4);
    }

    #[test]
    fn with_cells_masters_allocate_like_plain_masters() {
        let cluster = ClusterConfig::uniform(4, Res::cpu_gpu_ram(12.0, 0.0, 64.0));
        let dorm = DormConfig { theta1: 0.5, theta2: 0.5 };
        let cells = CellsConfig { count: 2, rebalance_every: 8, imbalance_threshold: 1.5 };
        let mut sharded = DormMaster::with_cells(&cluster, dorm, &cells, store("cells_m"));
        let mut plain = DormMaster::new(&cluster, dorm, store("cells_p"));
        let mut ids = Vec::new();
        // sized with slack so every app reaches n_max under either layout
        // (at an exact-fit point per-app totals could legally differ)
        for _ in 0..4 {
            let s = spec(2.0, 0.0, 8.0, 1, 2, 5);
            ids.push((sharded.submit(s.clone()).unwrap(), plain.submit(s).unwrap()));
        }
        for (a, b) in &ids {
            // same totals per app (placements may differ across cells)
            assert_eq!(sharded.containers_of(*a), plain.containers_of(*b));
        }
        let views = sharded.cell_views().expect("sharded master exposes cells");
        assert_eq!(views.len(), 2);
        assert_eq!(views.iter().map(|v| v.apps).sum::<u32>(), 4);
        assert!(plain.cell_views().is_none(), "unsharded policy has no cells");
    }
}

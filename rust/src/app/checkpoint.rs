//! Checkpoint store: the "reliable storage system" of §III-C-2.
//!
//! The paper parks application state on Lustre between the kill and resume
//! steps of the adjustment protocol; here the store is a directory of
//! checksummed binary files (DESIGN.md §1).  Writes are atomic
//! (tmp + rename) so a crash mid-save can never corrupt the latest good
//! checkpoint, and loads verify an FNV-1a digest so corruption is detected
//! rather than silently resumed from (failure-injection tested).

use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::spec::AppId;

const MAGIC: &[u8; 8] = b"DORMCKPT";
const VERSION: u32 = 1;

/// A point-in-time snapshot of a training application: the flat parameter
/// vector (L2 convention, DESIGN.md §5) plus the iteration cursor.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub app: AppId,
    /// Training step the parameters correspond to.
    pub step: u64,
    /// Model name (key into `artifacts/manifest.kv`).
    pub model: String,
    /// Last recorded training loss (diagnostic only).
    pub loss: f32,
    /// Flat f32 parameters.
    pub params: Vec<f32>,
}

pub(crate) fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

impl Checkpoint {
    /// Serialize to the on-disk format (little-endian, digest-terminated).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut buf = Vec::with_capacity(64 + self.params.len() * 4);
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.extend_from_slice(&self.app.0.to_le_bytes());
        buf.extend_from_slice(&self.step.to_le_bytes());
        buf.extend_from_slice(&(self.model.len() as u32).to_le_bytes());
        buf.extend_from_slice(self.model.as_bytes());
        buf.extend_from_slice(&self.loss.to_le_bytes());
        buf.extend_from_slice(&(self.params.len() as u64).to_le_bytes());
        for p in &self.params {
            buf.extend_from_slice(&p.to_le_bytes());
        }
        let digest = fnv1a(&buf);
        buf.extend_from_slice(&digest.to_le_bytes());
        buf
    }

    /// Parse + verify the digest.
    pub fn from_bytes(bytes: &[u8]) -> Result<Checkpoint> {
        if bytes.len() < MAGIC.len() + 4 + 8 {
            bail!("checkpoint truncated ({} bytes)", bytes.len());
        }
        let (body, digest_bytes) = bytes.split_at(bytes.len() - 8);
        let expect = u64::from_le_bytes(digest_bytes.try_into().unwrap());
        if fnv1a(body) != expect {
            bail!("checkpoint digest mismatch (corrupt file)");
        }
        let mut cur = body;
        let mut take = |n: usize| -> Result<&[u8]> {
            if cur.len() < n {
                bail!("checkpoint truncated");
            }
            let (head, rest) = cur.split_at(n);
            cur = rest;
            Ok(head)
        };
        if take(8)? != MAGIC {
            bail!("bad checkpoint magic");
        }
        let version = u32::from_le_bytes(take(4)?.try_into().unwrap());
        if version != VERSION {
            bail!("unsupported checkpoint version {version}");
        }
        let app = AppId(u64::from_le_bytes(take(8)?.try_into().unwrap()));
        let step = u64::from_le_bytes(take(8)?.try_into().unwrap());
        let name_len = u32::from_le_bytes(take(4)?.try_into().unwrap()) as usize;
        let model = String::from_utf8(take(name_len)?.to_vec())
            .context("checkpoint model name not utf-8")?;
        let loss = f32::from_le_bytes(take(4)?.try_into().unwrap());
        let n = u64::from_le_bytes(take(8)?.try_into().unwrap()) as usize;
        let raw = take(n * 4)?;
        let params = raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok(Checkpoint { app, step, model, loss, params })
    }
}

/// Directory-backed checkpoint store. One file per (app, step); `latest`
/// resolution picks the highest step with a valid digest.
#[derive(Clone, Debug)]
pub struct CheckpointStore {
    dir: PathBuf,
}

impl CheckpointStore {
    pub fn new(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
        Ok(CheckpointStore { dir })
    }

    fn path_for(&self, app: AppId, step: u64) -> PathBuf {
        self.dir.join(format!("{app}.step{step:012}.ckpt"))
    }

    /// Atomic save: write to a tmp file, fsync, rename into place.
    pub fn save(&self, ckpt: &Checkpoint) -> Result<PathBuf> {
        let final_path = self.path_for(ckpt.app, ckpt.step);
        let tmp = final_path.with_extension("tmp");
        {
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            f.write_all(&ckpt.to_bytes())?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, &final_path)?;
        Ok(final_path)
    }

    /// Load the newest valid checkpoint for `app`; corrupt files are
    /// skipped (with a warning) so a bad latest falls back to the previous.
    pub fn load_latest(&self, app: AppId) -> Result<Option<Checkpoint>> {
        let candidates = self.files_of(app)?;
        for path in candidates.iter().rev() {
            let mut bytes = Vec::new();
            std::fs::File::open(path)?.read_to_end(&mut bytes)?;
            match Checkpoint::from_bytes(&bytes) {
                Ok(c) => return Ok(Some(c)),
                Err(e) => {
                    log::warn!("skipping corrupt checkpoint {}: {e}", path.display());
                }
            }
        }
        Ok(None)
    }

    /// All checkpoint files of `app`, sorted ascending by step (the
    /// zero-padded step makes lexicographic == numeric).  The single home
    /// of the filename-scheme assumptions `load_latest`/`prune` share —
    /// external callers (tests, tooling) should use this rather than
    /// re-deriving the naming scheme.
    pub fn files_of(&self, app: AppId) -> Result<Vec<PathBuf>> {
        let prefix = format!("{app}.step");
        let mut out: Vec<PathBuf> = std::fs::read_dir(&self.dir)?
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .map_or(false, |n| n.starts_with(&prefix) && n.ends_with(".ckpt"))
            })
            .collect();
        out.sort();
        Ok(out)
    }

    /// Retention: keep only the newest `keep` checkpoints of `app`
    /// (failure-driven checkpointing makes them frequent; `crate::fault`).
    /// The newest *good* (digest-valid) snapshot is always kept even when
    /// it is older than the `keep` newest files — pruning must never turn
    /// a corrupt latest into an unrecoverable app.  Returns the number of
    /// files removed.
    pub fn prune(&self, app: AppId, keep: usize) -> Result<usize> {
        let files = self.files_of(app)?;
        if files.len() <= keep.max(1) {
            return Ok(0);
        }
        // newest file whose digest verifies, scanning newest-first
        let newest_good: Option<&PathBuf> = files.iter().rev().find(|p| {
            std::fs::read(p)
                .ok()
                .and_then(|b| Checkpoint::from_bytes(&b).ok())
                .is_some()
        });
        Self::prune_files(&files, keep, newest_good.map(|p| p.as_path()))
    }

    /// The shared quota rule: delete all but the newest `keep` files,
    /// never touching `protect`.
    fn prune_files(files: &[PathBuf], keep: usize, protect: Option<&Path>) -> Result<usize> {
        let keep = keep.max(1);
        if files.len() <= keep {
            return Ok(0);
        }
        let cut = files.len() - keep;
        let mut removed = 0;
        for p in &files[..cut] {
            if Some(p.as_path()) == protect {
                continue;
            }
            std::fs::remove_file(p)?;
            removed += 1;
        }
        Ok(removed)
    }

    /// Retention right after a successful save: `just_wrote` (the path
    /// [`CheckpointStore::save`] returned) is digest-valid by construction
    /// and is never deleted, so the newest-good digest re-scan of
    /// [`CheckpointStore::prune`] — which would re-read the bytes just
    /// written — is skipped.  The explicit path matters: after a rollback
    /// past a corrupt higher-step file, the fresh save is *not* the
    /// lexicographically newest file on disk, and "protect the newest"
    /// would delete the only restorable snapshot.  Only use on a save
    /// path; standalone cleanup must go through `prune`.
    pub fn prune_after_save(&self, app: AppId, keep: usize, just_wrote: &Path) -> Result<usize> {
        Self::prune_files(&self.files_of(app)?, keep, Some(just_wrote))
    }

    // ---- master self-checkpoints (HA, `crate::master::ha`) --------------
    //
    // The store also parks the *master's own* state: full snapshots named
    // `master.ep{epoch}.seq{seq}.mckpt` (zero-padded so lexicographic ==
    // (epoch, seq) order) plus one append-only `master.wal` of the
    // mutating requests since the newest snapshot.  The byte format lives
    // in `crate::master::ha`; this layer only does atomic file plumbing,
    // mirroring the per-app checkpoint discipline above.

    fn master_path(&self, epoch: u64, seq: u64) -> PathBuf {
        self.dir.join(format!("master.ep{epoch:010}.seq{seq:012}.mckpt"))
    }

    /// Atomically persist one master snapshot (tmp + fsync + rename, same
    /// crash discipline as [`CheckpointStore::save`]).
    pub fn save_master(&self, bytes: &[u8], epoch: u64, seq: u64) -> Result<PathBuf> {
        let final_path = self.master_path(epoch, seq);
        let tmp = final_path.with_extension("tmp");
        {
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            f.write_all(bytes)?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, &final_path)?;
        Ok(final_path)
    }

    /// All master snapshot files, ascending by (epoch, seq).
    pub fn master_files(&self) -> Result<Vec<PathBuf>> {
        let mut out: Vec<PathBuf> = std::fs::read_dir(&self.dir)?
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .map_or(false, |n| n.starts_with("master.ep") && n.ends_with(".mckpt"))
            })
            .collect();
        out.sort();
        Ok(out)
    }

    /// Retention for master snapshots: keep the newest `keep` files
    /// (clamped to ≥ 1).  Saves are atomic, so the newest file is whole by
    /// construction; digest validation (and fallback past a bit-rotted
    /// newest) happens at load time in `crate::master::ha`.
    pub fn prune_master(&self, keep: usize) -> Result<usize> {
        let files = self.master_files()?;
        let keep = keep.max(1);
        if files.len() <= keep {
            return Ok(0);
        }
        let cut = files.len() - keep;
        let mut removed = 0;
        for p in &files[..cut] {
            std::fs::remove_file(p)?;
            removed += 1;
        }
        Ok(removed)
    }

    /// The master write-ahead log (delta records between full snapshots).
    pub fn wal_path(&self) -> PathBuf {
        self.dir.join("master.wal")
    }

    /// Remove all checkpoints for a completed app.
    pub fn gc(&self, app: AppId) -> Result<usize> {
        let mut removed = 0;
        for e in std::fs::read_dir(&self.dir)? {
            let p = e?.path();
            if p.file_name()
                .and_then(|n| n.to_str())
                .map_or(false, |n| n.starts_with(&format!("{app}.step")))
            {
                std::fs::remove_file(&p)?;
                removed += 1;
            }
        }
        Ok(removed)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("dorm_ckpt_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn sample(app: u64, step: u64) -> Checkpoint {
        Checkpoint {
            app: AppId(app),
            step,
            model: "lr".into(),
            loss: 0.693,
            params: (0..257).map(|i| i as f32 * 0.5).collect(),
        }
    }

    #[test]
    fn roundtrip_bytes() {
        let c = sample(7, 42);
        let got = Checkpoint::from_bytes(&c.to_bytes()).unwrap();
        assert_eq!(got, c);
    }

    #[test]
    fn detects_corruption_anywhere() {
        let c = sample(1, 1);
        let bytes = c.to_bytes();
        for pos in [0, 8, 20, bytes.len() / 2, bytes.len() - 1] {
            let mut bad = bytes.clone();
            bad[pos] ^= 0xFF;
            assert!(
                Checkpoint::from_bytes(&bad).is_err(),
                "corruption at {pos} undetected"
            );
        }
        assert!(Checkpoint::from_bytes(&bytes[..10]).is_err());
    }

    #[test]
    fn store_save_load_latest() {
        let store = CheckpointStore::new(tmpdir("latest")).unwrap();
        store.save(&sample(3, 10)).unwrap();
        store.save(&sample(3, 200)).unwrap();
        store.save(&sample(4, 999)).unwrap(); // other app
        let got = store.load_latest(AppId(3)).unwrap().unwrap();
        assert_eq!(got.step, 200);
        assert!(store.load_latest(AppId(99)).unwrap().is_none());
    }

    #[test]
    fn corrupt_latest_falls_back_to_previous() {
        let store = CheckpointStore::new(tmpdir("fallback")).unwrap();
        store.save(&sample(5, 1)).unwrap();
        let p = store.save(&sample(5, 2)).unwrap();
        // corrupt the newest file
        let mut bytes = std::fs::read(&p).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&p, bytes).unwrap();
        let got = store.load_latest(AppId(5)).unwrap().unwrap();
        assert_eq!(got.step, 1, "should fall back to the older checkpoint");
    }

    #[test]
    fn prune_keeps_newest_n() {
        let store = CheckpointStore::new(tmpdir("prune")).unwrap();
        for step in 1..=5 {
            store.save(&sample(9, step)).unwrap();
        }
        store.save(&sample(10, 1)).unwrap(); // other app untouched
        assert_eq!(store.prune(AppId(9), 2).unwrap(), 3);
        assert_eq!(store.load_latest(AppId(9)).unwrap().unwrap().step, 5);
        // steps 4 and 5 survive: corrupting 5 must still fall back to 4
        let p5 = store.path_for(AppId(9), 5);
        let mut bytes = std::fs::read(&p5).unwrap();
        let n = bytes.len();
        bytes[n - 1] ^= 0xFF;
        std::fs::write(&p5, bytes).unwrap();
        assert_eq!(store.load_latest(AppId(9)).unwrap().unwrap().step, 4);
        assert_eq!(store.load_latest(AppId(10)).unwrap().unwrap().step, 1);
        assert_eq!(store.prune(AppId(9), 2).unwrap(), 0, "already at quota");
    }

    #[test]
    fn prune_never_deletes_newest_good_snapshot() {
        let store = CheckpointStore::new(tmpdir("prune_good")).unwrap();
        store.save(&sample(11, 1)).unwrap();
        store.save(&sample(11, 2)).unwrap();
        let p3 = store.save(&sample(11, 3)).unwrap();
        // newest is corrupt: a naive keep-1 would delete the only good copies
        let mut bytes = std::fs::read(&p3).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xAA;
        std::fs::write(&p3, bytes).unwrap();
        store.prune(AppId(11), 1).unwrap();
        let got = store.load_latest(AppId(11)).unwrap().unwrap();
        assert_eq!(got.step, 2, "newest good snapshot must survive pruning");
        // keep = 0 is clamped to 1, never emptying the store
        store.prune(AppId(11), 0).unwrap();
        assert!(store.load_latest(AppId(11)).unwrap().is_some());
    }

    #[test]
    fn prune_after_save_enforces_quota_cheaply() {
        let store = CheckpointStore::new(tmpdir("prune_fast")).unwrap();
        let mut last = std::path::PathBuf::new();
        for step in 1..=4 {
            last = store.save(&sample(14, step)).unwrap();
            store.prune_after_save(AppId(14), 2, &last).unwrap();
        }
        assert_eq!(store.load_latest(AppId(14)).unwrap().unwrap().step, 4);
        assert_eq!(store.files_of(AppId(14)).unwrap().len(), 2);
        assert_eq!(store.prune_after_save(AppId(14), 2, &last).unwrap(), 0);
    }

    #[test]
    fn prune_after_save_protects_a_rolled_back_write() {
        let store = CheckpointStore::new(tmpdir("prune_rollback")).unwrap();
        // a corrupt high-step file lingers; after the rollback the app
        // saves a LOWER step — retention must not delete the fresh good
        // file in favour of the corrupt "newest"
        let p200 = store.save(&sample(16, 200)).unwrap();
        let mut bytes = std::fs::read(&p200).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x11;
        std::fs::write(&p200, bytes).unwrap();
        let p150 = store.save(&sample(16, 150)).unwrap();
        store.prune_after_save(AppId(16), 1, &p150).unwrap();
        let got = store.load_latest(AppId(16)).unwrap().unwrap();
        assert_eq!(got.step, 150, "just-written snapshot must survive pruning");
    }

    #[test]
    fn gc_removes_all_for_app() {
        let store = CheckpointStore::new(tmpdir("gc")).unwrap();
        store.save(&sample(6, 1)).unwrap();
        store.save(&sample(6, 2)).unwrap();
        store.save(&sample(7, 1)).unwrap();
        assert_eq!(store.gc(AppId(6)).unwrap(), 2);
        assert!(store.load_latest(AppId(6)).unwrap().is_none());
        assert!(store.load_latest(AppId(7)).unwrap().is_some());
    }

    #[test]
    fn big_params_roundtrip() {
        let mut c = sample(8, 3);
        c.params = (0..100_000).map(|i| (i as f32).sin()).collect();
        let got = Checkpoint::from_bytes(&c.to_bytes()).unwrap();
        assert_eq!(got.params.len(), 100_000);
        assert_eq!(got, c);
    }
}

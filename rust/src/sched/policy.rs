//! The policy interface: what any cluster-management strategy sees
//! (a backend-neutral snapshot) and what it returns (a full assignment).

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::app::{AppId, Engine};
use crate::cluster::{Assignment, ServerId};
use crate::resources::Res;

use super::{CellView, CellsSnapshot, EngineStats};

/// One application as a policy sees it — the fields every backend (live
/// master, DES) can provide, and everything any policy needs.
#[derive(Clone, Debug)]
pub struct SchedApp {
    pub id: AppId,
    /// Per-container demand `d` (uniform containers, §III-A-4).
    pub demand: Res,
    /// Weight `w` as a float (the optimizer's wᵢ).
    pub weight: f64,
    pub n_min: u32,
    pub n_max: u32,
    /// Containers currently held (0 = pending / deferred).
    pub containers: u32,
    /// Current xᵢⱼ row (empty when `containers == 0`).
    pub placement: BTreeMap<ServerId, u32>,
    /// FIFO admission key: earlier submissions admitted first, the newest
    /// pending app deferred first on infeasibility (§IV-B).  The DES uses
    /// simulated hours; the live master uses submission order.
    pub submit: f64,
    /// Fixed partition size a static (Swarm/Mesos app-level) policy gives
    /// this app; ignored by Dorm.  Backend caveat: the DES fills this from
    /// the workload's per-type baseline width (§V-A-4), while the live
    /// master — whose submission 6-tuple carries no baseline column — uses
    /// `n_max` (the requested width).  Dorm decisions are identical across
    /// backends (`tests/parity.rs`); static-policy widths are only
    /// comparable across backends when the submission's `n_max` equals the
    /// workload baseline.
    pub baseline_n: u32,
    /// Requested DCS engine — the IaaS baseline partitions servers by it.
    pub engine: Engine,
}

/// Read-only snapshot handed to policies on every arrival/completion.
pub struct SchedCtx<'a> {
    /// Event time (simulated hours in the DES, event counter on the live
    /// master); only used for ordering/latency bookkeeping, never solved on.
    pub now: f64,
    /// Active (admitted-or-pending, non-terminal) applications.
    pub apps: &'a BTreeMap<AppId, SchedApp>,
    /// Per-server capacities, indexed by [`ServerId`].
    pub capacities: &'a [Res],
}

/// A policy's decision: the complete next assignment for every active app
/// (apps omitted keep zero containers), plus which carried-over apps were
/// adjusted (checkpointed + killed + resumed at the new scale).
///
/// The assignment is shared ([`Arc`]) so stateful policies serving cached
/// decisions hand it out in O(1); backends only read it.
#[derive(Clone, Debug, Default)]
pub struct AllocationUpdate {
    pub assignment: Arc<Assignment>,
    pub adjusted: Vec<AppId>,
}

/// A cluster-management policy.  Implementations decide assignments only;
/// enforcement (container create/destroy, checkpoint/kill/resume) belongs
/// to the backend driving the policy.
///
/// `Send` because the network server hands the master (and the boxed
/// policy inside it) to connection threads, and the sharded
/// [`super::CellScheduler`] solves cells on scoped worker threads.
pub trait CmsPolicy: Send {
    fn name(&self) -> String;

    /// Called after every arrival and completion. `None` = keep current
    /// allocations (e.g. no feasible solution, paper §IV-B).
    fn on_change(&mut self, ctx: &SchedCtx) -> Option<AllocationUpdate>;

    /// Admission/scheduling latency charged to newly started apps (used by
    /// the Mesos-like baseline; Dorm's is ~solver time, effectively 0 at
    /// hour scale).
    fn admission_latency_hours(&self) -> f64 {
        0.0
    }

    /// Out-of-band capacity change: any solve state derived from the old
    /// capacity vector — snapshot cache, warm-start incumbent — must be
    /// dropped.  Three dispatched control-plane events drive this
    /// (`crate::proto`, DESIGN.md §9): a server died (lease expiry /
    /// `FailServer`), a server came back (`RecoverServer`), or a
    /// heartbeat's `SlaveReport` announced a different hardware capacity
    /// than the master's book (the slave is authoritative; the master
    /// adopts it and re-solves).  Both backends (live master and DES)
    /// call this at the same points so stateful policies stay
    /// decision-identical across them — and `tests/transport_parity.rs`
    /// extends that parity across transports.  Default: no-op (the
    /// baselines are stateless).
    fn on_capacity_change(&mut self) {}

    /// A specific server was observed dead at `now` (lease expiry,
    /// `FailServer`, DES `ServerFail`) — finer-grained than
    /// [`CmsPolicy::on_capacity_change`], which always follows.  Risk-aware
    /// policies feed this to their online [`crate::fault::MtbfEstimator`];
    /// both backends call it at the same points (immediately before the
    /// capacity-change invalidation) so stateful estimators stay
    /// decision-identical across them.  Default: no-op.
    fn on_server_failed(&mut self, _server: ServerId, _now: f64) {}

    /// A specific server was observed back at `now` (`RecoverServer`,
    /// re-register, DES `ServerRecover`).  Default: no-op.
    fn on_server_recovered(&mut self, _server: ServerId, _now: f64) {}

    /// Multiplier on application progress under this CMS, in (0, 1].
    /// Below 1 models per-task scheduling overhead: task-level sharing
    /// (§II-C) pays the central manager's latency on every ~1.5 s task,
    /// shaving throughput even though placements match the static policy.
    fn progress_factor(&self) -> f64 {
        1.0
    }

    /// Incremental-path telemetry, when the policy runs an
    /// [`crate::sched::AllocationEngine`] (cache hits, warm starts, delta
    /// packs…).  Backends surface it for observability; the stateless
    /// baselines return `None`.
    fn engine_stats(&self) -> Option<EngineStats> {
        None
    }

    /// Per-cell observability when the policy shards the cluster
    /// ([`super::CellScheduler`]).  Unsharded policies return `None`.
    fn cell_views(&self) -> Option<Vec<CellView>> {
        None
    }

    /// The persistent cell map (routing pins + partition parameters) the
    /// master's HA checkpoint carries so a standby rebuilds the same
    /// sharding.  Unsharded policies return `None`.
    fn cells_snapshot(&self) -> Option<CellsSnapshot> {
        None
    }
}

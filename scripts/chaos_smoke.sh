#!/usr/bin/env bash
# Chaos smoke for correlated failure domains (DESIGN.md §14): one master
# whose slaves are named across two racks (rack0: s0 s1, rack1: s2 s3),
# four slave agents as real processes over TCP, then `kill -9` the whole
# of rack0 at once and assert that
#   * the lease expiry reaps BOTH rack0 servers as ONE batch,
#   * the batch costs exactly one re-solve — each spanning app records
#     exactly one recovery (rollback), not one per dead server, and
#   * the surviving rack keeps making progress: steps advance past the
#     restored checkpoint and a fresh submission schedules on rack1.
# Run from the repo root after `cargo build --release`; exits non-zero on
# any failed step.
set -euo pipefail

BIN=${BIN:-rust/target/release/dorm}
PORT=${PORT:-46031}
ADDR=127.0.0.1:$PORT
STORE=$(mktemp -d)
LOG=$(mktemp -d)
MASTER_PID=
SLAVE_PIDS=()

cleanup() {
  for pid in "${SLAVE_PIDS[@]:-}" "$MASTER_PID"; do
    [ -n "$pid" ] && kill "$pid" 2>/dev/null || true
  done
  rm -rf "$STORE" "$LOG"
}
trap cleanup EXIT

fail() {
  echo "CHAOS SMOKE FAIL: $1" >&2
  for f in master slave0 slave1 slave2 slave3; do
    echo "--- $f log ---" >&2; cat "$LOG/$f.log" >&2 2>/dev/null || true
  done
  exit 1
}

ctl() {
  "$BIN" ctl --connect "$ADDR" "$@"
}

wait_for() { # wait_for <file> <pattern> <tries> <what>
  for _ in $(seq 1 "$3"); do
    grep -q "$2" "$1" 2>/dev/null && return 0
    sleep 0.1
  done
  fail "$4"
}

echo "== starting master: 4 slaves in 2 racks, lease 1000 ms, manual sweeps"
"$BIN" master --bind "$ADDR" --slaves 4 --racks 2 --theta1 0.5 --theta2 0.5 \
  --lease-ms 1000 --sweep-ms 0 --store "$STORE" >"$LOG/master.log" 2>&1 &
MASTER_PID=$!
wait_for "$LOG/master.log" "listening" 50 "master never started listening"
grep -q "2 racks" "$LOG/master.log" \
  || fail "master did not derive the rack topology (--racks 2)"

echo "== starting 4 slave agents (rack0: 0 1, rack1: 2 3)"
for i in 0 1 2 3; do
  "$BIN" slave --connect "$ADDR" --index "$i" --period-ms 150 \
    >"$LOG/slave$i.log" 2>&1 &
  SLAVE_PIDS+=($!)
done

echo "== drive workload: app1 spans both racks, checkpoint at step 100"
ctl submit --cpu 2 --ram 8 --nmax 8 | grep -q "submitted app1" || fail "submit app1"
ctl advance --app 1 --steps 100 | grep -q ok || fail "advance app1"
ctl checkpoint --app 1 | grep -q ok || fail "checkpoint app1"
ctl advance --app 1 --steps 25 | grep -q ok || fail "advance app1 past ckpt"
wait_for "$LOG/slave0.log" "applied" 100 "rack0 never applied directives"

PRE=$(ctl query)
echo "$PRE" | grep -q "servers=4/4" || fail "expected 4/4 alive pre-kill: $PRE"
echo "$PRE" | grep -q "app1 Running containers=8 steps=125 ckpt=100" \
  || fail "unexpected pre-kill app1 state: $PRE"

echo "== kill -9 the whole of rack0 (slaves 0 and 1) at once"
kill -9 "${SLAVE_PIDS[0]}" "${SLAVE_PIDS[1]}" || fail "could not kill rack0"
SLAVE_PIDS[0]=
SLAVE_PIDS[1]=

echo "== one expiry sweep past the lease must reap BOTH as ONE batch"
sleep 1.3   # lease is 1000 ms; rack1 keeps heartbeating every 150 ms
EXP=$(ctl expire)
echo "$EXP" | grep -q "expired servers \[0, 1\]" \
  || fail "rack0 did not expire as one batch: $EXP"
kill -0 "$MASTER_PID" 2>/dev/null || fail "master died during the rack outage"

POST=$(ctl query)
echo "$POST" | grep -q "servers=2/4" || fail "expected 2/4 alive post-kill: $POST"
# one batch -> one whole-app rollback -> rec=1 exactly; two separate
# expiries would have rolled app1 back (and re-solved) twice
echo "$POST" | grep -Eq "app1 Running containers=[0-9]+ steps=100 ckpt=100 adj=[0-9]+ rec=1" \
  || fail "whole-rack kill must cost exactly one rollback to ckpt 100: $POST"

echo "== surviving rack progresses: advance past the restored checkpoint"
ctl advance --app 1 --steps 10 | grep -q ok || fail "advance app1 post-kill"
ctl query | grep -q "steps=110" || fail "app1 did not progress post-kill: $(ctl query)"

echo "== a fresh submission schedules on the surviving rack"
ctl submit --cpu 2 --ram 8 --nmax 2 | grep -q "submitted app2" || fail "submit app2"
for _ in $(seq 1 50); do
  if ctl query | grep -q "app2 Running containers=2"; then break; fi
  sleep 0.1
done
ctl query | grep -q "app2 Running containers=2" \
  || fail "post-kill submit did not run on rack1: $(ctl query)"

echo "== shutdown: master exits, rack1 slaves drain"
ctl shutdown | grep -q ok || fail "shutdown"
for pid in "${SLAVE_PIDS[2]}" "${SLAVE_PIDS[3]}"; do
  for _ in $(seq 1 200); do
    kill -0 "$pid" 2>/dev/null || break
    sleep 0.1
  done
  if kill -0 "$pid" 2>/dev/null; then
    fail "rack1 slave $pid still running after the master left"
  fi
done
SLAVE_PIDS=()
MASTER_PID=

echo "CHAOS SMOKE PASS: rack0 kill -9 -> one batch expiry -> one re-solve -> rack1 progresses"

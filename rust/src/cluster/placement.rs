//! Placement round: map per-application container *counts* onto concrete
//! servers (the xᵢⱼ of P2), keeping unadjusted applications pinned.
//!
//! Eq. 3 counts an application as adjusted if **any** xᵢⱼ changes, so the
//! placement round must (a) leave apps whose count is unchanged exactly
//! where they are and (b) re-place adjusted apps by best-fit-decreasing on
//! the dominant share — the classic FFD/BFD bin-packing heuristic, which at
//! paper scale (uniform containers, 20 servers) packs whatever the
//! aggregate-capacity check admits; when it cannot, the optimizer retries
//! with reduced counts (see [`crate::optimizer`]).
//!
//! Two entry points (DESIGN.md §10):
//!
//! * [`place`] — the full round: movers release everything and are
//!   re-packed best-fit-decreasing.  Best fit runs over a slack-ordered
//!   server heap ([`fill_best_fit`]) instead of a per-container linear
//!   scan, so packing c containers onto s servers costs ~O(c log s).
//! * [`place_delta`] — the incremental round: a persistent [`PackState`]
//!   carries the per-server free-capacity vector across solves, shrinking
//!   apps release containers in place, growing apps add containers without
//!   disturbing their existing row, and only when a grow cannot fit does
//!   the round fall back to the full BFD re-pack.  This is the hot path of
//!   the allocation engine's per-event decision loop.
//!
//! Both paths emit *net* `destroy`/`create` deltas: an (app, server) pair
//! whose container count ends where it started never appears in either
//! list, so the Eq. 3 adjusted set is not overstated by movers that land
//! back on the exact same servers.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BTreeSet, BinaryHeap};
use std::sync::Arc;

use crate::app::AppId;
use crate::resources::Res;

use super::ServerId;

/// Final xᵢⱼ: one row of (server → container count) per application.
pub type Assignment = BTreeMap<AppId, BTreeMap<ServerId, u32>>;

/// One application's placement request.
#[derive(Clone, Debug)]
pub struct PlacementInput {
    pub app: AppId,
    pub demand: Res,
    /// Target total containers (the optimizer's nᵢ).
    pub target: u32,
    /// Current placement (empty for new apps).
    pub current: BTreeMap<ServerId, u32>,
}

/// Result: per-app server assignment plus the create/destroy delta.
#[derive(Clone, Debug, Default)]
pub struct Placement {
    /// Final xᵢⱼ (shared so cached decisions hand it out without copying).
    pub assignment: Arc<Assignment>,
    /// Net containers to destroy, per app per server (before creates).
    pub destroy: Vec<(AppId, ServerId, u32)>,
    /// Net containers to create, per app per server.
    pub create: Vec<(AppId, ServerId, u32)>,
    /// True when the delta packer produced this placement without a full
    /// BFD re-pack (see [`place_delta`]).
    pub delta_path: bool,
}

impl Placement {
    /// Apps whose placement changed (rᵢ = 1 in Eq. 3 terms).
    pub fn adjusted_apps(&self) -> Vec<AppId> {
        let mut apps: Vec<AppId> = self
            .destroy
            .iter()
            .chain(self.create.iter())
            .map(|&(a, _, _)| a)
            .collect();
        apps.sort();
        apps.dedup();
        apps
    }

    /// Containers this placement physically moves (Σ destroys + Σ creates)
    /// — the churn the delta packer exists to minimize.
    pub fn moved_containers(&self) -> u64 {
        self.destroy
            .iter()
            .chain(self.create.iter())
            .map(|&(_, _, c)| c as u64)
            .sum()
    }
}

/// Best-fit key for placing one `demand` container on free capacity `f`:
/// the post-placement dominant-share slack, as ordered bits (slacks are
/// non-negative, so the IEEE bit pattern orders like the float).
fn slack_bits(f: &Res, demand: &Res, total_cap: &Res) -> u64 {
    f.clone()
        .saturating_sub(demand)
        .dominant_share(total_cap)
        .to_bits()
}

/// Failure-domain context for risk-aware placement (DESIGN.md §14): which
/// rack each server lives in and how risky the online
/// [`crate::fault::MtbfEstimator`] currently believes each rack is.
///
/// Strictly a **tie-break**: servers are still chosen by least
/// post-placement dominant-share slack first, so allocation totals (the
/// optimizer's nᵢ) are untouched — only *which* equal-slack server wins
/// changes.  At equal slack the fill prefers (a) the server in the
/// lowest-risk domain, then (b) the domain holding the fewest of this
/// app's containers placed so far in this fill (spread), then (c) the
/// lowest server index — so with no risk evidence and a single domain the
/// order reduces exactly to today's `(slack, index)`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SpreadCtx {
    /// Failure-domain (rack) index per server ordinate.
    pub domain_of: Vec<usize>,
    /// Estimated failure rate per domain (higher = riskier; 0 = no
    /// evidence).
    pub risk: Vec<f64>,
}

impl SpreadCtx {
    fn domain(&self, j: usize) -> usize {
        self.domain_of.get(j).copied().unwrap_or(0)
    }

    /// Risk of server `j`'s domain as ordered bits (rates are
    /// non-negative, so IEEE bits order like the float).
    fn risk_bits(&self, j: usize) -> u64 {
        self.risk
            .get(self.domain(j))
            .copied()
            .unwrap_or(0.0)
            .max(0.0)
            .to_bits()
    }

    fn n_domains(&self) -> usize {
        self.domain_of
            .iter()
            .map(|&d| d + 1)
            .max()
            .unwrap_or(0)
            .max(self.risk.len())
    }
}

/// Place `count` identical `demand`-sized containers by repeated best fit
/// (feasible server with the least post-placement dominant-share slack,
/// lowest index on ties — byte-identical to a per-container linear scan)
/// using a slack-ordered min-heap: build O(s), then O(log s) per
/// container.  Heap entries are invalidated lazily: a popped entry whose
/// key no longer matches the live free vector is re-keyed and re-pushed
/// rather than the index being rebuilt, so callers may mutate `free`
/// between fills without bookkeeping.  On failure `free` is rolled back
/// (the fill is atomic).
///
/// With a [`SpreadCtx`] the heap key grows two middle components —
/// `(slack, domain risk, app containers already in domain, index)` — so
/// equal-slack ties resolve away from at-risk domains and toward domain
/// spread; without one both components are constant 0 and the order is
/// exactly the historical `(slack, index)`.
fn fill_best_fit(
    demand: &Res,
    count: u32,
    free: &mut [Res],
    total_cap: &Res,
    spread: Option<&SpreadCtx>,
) -> Option<BTreeMap<ServerId, u32>> {
    let mut assigned: BTreeMap<ServerId, u32> = BTreeMap::new();
    if count == 0 {
        return Some(assigned);
    }
    // containers of *this* fill placed per domain so far (spread term)
    let mut domain_used: Vec<u32> = vec![0; spread.map(|s| s.n_domains()).unwrap_or(0)];
    let key = |f: &Res, j: usize, domain_used: &[u32]| -> (u64, u64, u32, usize) {
        let (risk, used) = match spread {
            Some(s) => (
                s.risk_bits(j),
                domain_used.get(s.domain(j)).copied().unwrap_or(0),
            ),
            None => (0, 0),
        };
        (slack_bits(f, demand, total_cap), risk, used, j)
    };
    let mut heap: BinaryHeap<Reverse<(u64, u64, u32, usize)>> = free
        .iter()
        .enumerate()
        .filter(|(_, f)| demand.fits_in(f))
        .map(|(j, f)| Reverse(key(f, j, &domain_used)))
        .collect();
    for _ in 0..count {
        let j = loop {
            let Some(Reverse(k)) = heap.pop() else {
                // atomic: undo the partial fill before reporting failure
                for (sid, cnt) in &assigned {
                    free[sid.0] += &demand.times(*cnt);
                }
                return None;
            };
            let j = k.3;
            if !demand.fits_in(&free[j]) {
                continue; // stale: no longer feasible, drop lazily
            }
            let live = key(&free[j], j, &domain_used);
            if live != k {
                heap.push(Reverse(live)); // stale: re-key lazily
                continue;
            }
            break j;
        };
        free[j] -= demand;
        if let Some(s) = spread {
            let d = s.domain(j);
            if d < domain_used.len() {
                domain_used[d] += 1;
            }
        }
        *assigned.entry(ServerId(j)).or_insert(0) += 1;
        if demand.fits_in(&free[j]) {
            heap.push(Reverse(key(&free[j], j, &domain_used)));
        }
    }
    Some(assigned)
}

/// Append the net per-server delta between `old` and `new` rows of `app`.
fn net_deltas(
    app: AppId,
    old: &BTreeMap<ServerId, u32>,
    new: &BTreeMap<ServerId, u32>,
    destroy: &mut Vec<(AppId, ServerId, u32)>,
    create: &mut Vec<(AppId, ServerId, u32)>,
) {
    for (&sid, &was) in old {
        let now = new.get(&sid).copied().unwrap_or(0);
        if was > now {
            destroy.push((app, sid, was - now));
        }
    }
    for (&sid, &now) in new {
        let was = old.get(&sid).copied().unwrap_or(0);
        if now > was {
            create.push((app, sid, now - was));
        }
    }
}

/// Compute a placement for the given targets on servers with `capacity`.
///
/// Returns `None` if the targets cannot be packed (caller reduces counts
/// and retries).  Unchanged apps (target == current total) keep their exact
/// xᵢⱼ row; changed apps release all containers and are re-packed
/// best-fit-decreasing (deltas are netted, so containers that land back on
/// their original server are neither destroyed nor created).
pub fn place(inputs: &[PlacementInput], capacities: &[Res]) -> Option<Placement> {
    place_spread(inputs, capacities, None)
}

/// [`place`] with a failure-domain tie-break: identical contract and
/// identical per-app totals, but equal-slack choices prefer low-risk
/// domains and domain spread (see [`SpreadCtx`]).  `spread = None` is
/// byte-identical to [`place`].
pub fn place_spread(
    inputs: &[PlacementInput],
    capacities: &[Res],
    spread: Option<&SpreadCtx>,
) -> Option<Placement> {
    let m = capacities.first().map(|c| c.m()).unwrap_or(0);
    let mut free: Vec<Res> = capacities.to_vec();

    // Phase 1: pin unchanged apps and subtract their usage.
    let mut assignment: Assignment = BTreeMap::new();
    let mut movers: Vec<&PlacementInput> = Vec::new();
    for inp in inputs {
        let cur_total: u32 = inp.current.values().sum();
        if cur_total == inp.target && inp.target > 0 {
            for (&sid, &cnt) in &inp.current {
                let need = inp.demand.times(cnt);
                if !need.fits_in(&free[sid.0]) {
                    // existing state exceeds capacity — corrupted input
                    return None;
                }
                free[sid.0] -= &need;
            }
            assignment.insert(inp.app, inp.current.clone());
        } else {
            movers.push(inp);
        }
    }

    // Phase 2: movers are re-packed best-fit-decreasing by dominant
    // demand (their current containers were never charged to `free`, so
    // releasing them is implicit).
    let total_cap = capacities.iter().fold(Res::zeros(m), |mut acc, c| {
        acc += c;
        acc
    });
    let mut order: Vec<usize> = (0..movers.len()).collect();
    order.sort_by(|&a, &b| {
        let da = movers[a].demand.dominant_share(&total_cap);
        let db = movers[b].demand.dominant_share(&total_cap);
        db.total_cmp(&da)
    });

    for &idx in &order {
        let inp = movers[idx];
        let assigned = fill_best_fit(&inp.demand, inp.target, &mut free, &total_cap, spread)?;
        assignment.insert(inp.app, assigned);
    }

    // Phase 3: net out the per-(app, server) deltas.
    let mut out = Placement {
        assignment: Arc::new(assignment),
        ..Default::default()
    };
    for inp in &movers {
        let new_row = &out.assignment[&inp.app];
        net_deltas(inp.app, &inp.current, new_row, &mut out.destroy, &mut out.create);
    }
    Some(out)
}

/// One tracked application inside [`PackState`].
#[derive(Clone, Debug)]
struct Tracked {
    demand: Res,
    row: BTreeMap<ServerId, u32>,
}

/// Exact free-vector resync cadence (guards against f64 drift from long
/// chains of incremental +=/-=; see [`PackState`]).
const RESYNC_EVERY: u32 = 64;

/// Persistent state of the delta-aware packer: the per-server free-capacity
/// vector and the last committed placement rows, carried across solves so
/// consecutive placement rounds touch only the apps whose counts changed.
///
/// Owned by the caller running consecutive rounds (the allocation engine,
/// one per backend).  The state self-heals: every [`place_delta`] call
/// reconciles the tracked rows against the inputs' ground-truth `current`
/// placements, so failed enforcement, fault recovery or an abandoned plan
/// (the optimizer's reduce-counts retry) only cost a patch, never
/// corruption.  Every [`RESYNC_EVERY`] commits the free vector is rebuilt
/// exactly from the tracked rows to cancel float drift.
#[derive(Clone, Debug, Default)]
pub struct PackState {
    ready: bool,
    /// Bit signature of the capacity vector the state was built against —
    /// any change (server death/recovery, reported capacity) forces a
    /// rebuild.
    caps_bits: Vec<Vec<u64>>,
    /// capacity − Σ tracked rows, per server.
    free: Vec<Res>,
    tracked: BTreeMap<AppId, Tracked>,
    since_sync: u32,
    /// Failure-domain tie-break context; orthogonal to the packing books
    /// (survives [`PackState::invalidate`] — risk knowledge outlives a
    /// capacity change, which is exactly when it matters).
    spread: Option<SpreadCtx>,
}

impl PackState {
    /// Drop everything; the next [`place_delta`] rebuilds from its inputs.
    /// The [`SpreadCtx`] is deliberately kept: it describes the world, not
    /// the books.
    pub fn invalidate(&mut self) {
        self.ready = false;
        self.caps_bits.clear();
        self.free.clear();
        self.tracked.clear();
        self.since_sync = 0;
    }

    /// Install (or clear) the failure-domain tie-break context used by
    /// every subsequent [`place_delta`] fill and full-re-pack fallback.
    /// Does not invalidate the packing state — the tie-break only affects
    /// future equal-slack choices.
    pub fn set_spread(&mut self, spread: Option<SpreadCtx>) {
        self.spread = spread;
    }

    /// The installed failure-domain context, if any.
    pub fn spread(&self) -> Option<&SpreadCtx> {
        self.spread.as_ref()
    }

    /// True once the state carries a committed free vector.
    pub fn is_warm(&self) -> bool {
        self.ready
    }

    /// Rebuild from scratch: free = capacities − Σ inputs' current rows.
    /// `None` if some current row exceeds capacity (corrupted input, the
    /// same contract as [`place`]).
    fn rebuild(
        &mut self,
        inputs: &[PlacementInput],
        capacities: &[Res],
        caps_bits: Vec<Vec<u64>>,
    ) -> Option<()> {
        self.invalidate();
        self.free = capacities.to_vec();
        for inp in inputs {
            if inp.current.is_empty() {
                continue;
            }
            for (&sid, &cnt) in &inp.current {
                let need = inp.demand.times(cnt);
                if sid.0 >= self.free.len() || !need.fits_in(&self.free[sid.0]) {
                    self.invalidate();
                    return None;
                }
                self.free[sid.0] -= &need;
            }
            self.tracked.insert(
                inp.app,
                Tracked { demand: inp.demand.clone(), row: inp.current.clone() },
            );
        }
        self.caps_bits = caps_bits;
        self.ready = true;
        Some(())
    }

    /// Patch the state to match the inputs' ground truth: departed apps
    /// release their rows, apps whose current row or demand diverged from
    /// the tracked copy are re-charged.  `None` on anomaly (caller
    /// rebuilds).
    fn reconcile(&mut self, inputs: &[PlacementInput]) -> Option<()> {
        let live: BTreeSet<AppId> = inputs.iter().map(|i| i.app).collect();
        let departed: Vec<AppId> = self
            .tracked
            .keys()
            .filter(|&a| !live.contains(a))
            .copied()
            .collect();
        for app in departed {
            let t = self.tracked.remove(&app).expect("key just listed");
            for (&sid, &cnt) in &t.row {
                self.free[sid.0] += &t.demand.times(cnt);
            }
        }
        for inp in inputs {
            let unchanged = self
                .tracked
                .get(&inp.app)
                .is_some_and(|t| t.row == inp.current && t.demand == inp.demand);
            if unchanged {
                continue;
            }
            if let Some(t) = self.tracked.remove(&inp.app) {
                for (&sid, &cnt) in &t.row {
                    self.free[sid.0] += &t.demand.times(cnt);
                }
            }
            if inp.current.is_empty() {
                continue;
            }
            for (&sid, &cnt) in &inp.current {
                let need = inp.demand.times(cnt);
                if sid.0 >= self.free.len() || !need.fits_in(&self.free[sid.0]) {
                    return None;
                }
                self.free[sid.0] -= &need;
            }
            self.tracked.insert(
                inp.app,
                Tracked { demand: inp.demand.clone(), row: inp.current.clone() },
            );
        }
        Some(())
    }

    /// Adopt a full re-pack's result as the new committed state.
    fn adopt(&mut self, p: &Placement, inputs: &[PlacementInput], capacities: &[Res]) {
        let caps_bits = caps_sig(capacities);
        self.invalidate();
        self.free = capacities.to_vec();
        for inp in inputs {
            let Some(row) = p.assignment.get(&inp.app) else { continue };
            if row.is_empty() {
                continue;
            }
            for (&sid, &cnt) in row {
                self.free[sid.0] -= &inp.demand.times(cnt);
            }
            self.tracked.insert(
                inp.app,
                Tracked { demand: inp.demand.clone(), row: row.clone() },
            );
        }
        self.caps_bits = caps_bits;
        self.ready = true;
    }

    /// Exact recomputation of the free vector from the tracked rows.
    fn resync_free(&mut self, capacities: &[Res]) {
        self.free = capacities.to_vec();
        for t in self.tracked.values() {
            for (&sid, &cnt) in &t.row {
                self.free[sid.0] -= &t.demand.times(cnt);
            }
        }
        self.since_sync = 0;
    }
}

fn caps_sig(capacities: &[Res]) -> Vec<Vec<u64>> {
    capacities
        .iter()
        .map(|c| c.0.iter().map(|v| v.to_bits()).collect())
        .collect()
}

/// Full-re-pack escape hatch shared by every delta failure mode: run
/// [`place`], adopt its result into the state on success, mark the state
/// cold on failure (the next call rebuilds from ground truth).
fn fallback_full(
    inputs: &[PlacementInput],
    capacities: &[Res],
    state: &mut PackState,
) -> Option<Placement> {
    let spread = state.spread.clone();
    match place_spread(inputs, capacities, spread.as_ref()) {
        Some(full) => {
            state.adopt(&full, inputs, capacities);
            Some(full)
        }
        None => {
            state.ready = false;
            None
        }
    }
}

/// Delta-aware placement round: the same contract as [`place`], but moving
/// *only* apps whose container count changed.
///
/// * unchanged apps (target == current total) are never touched — they do
///   not appear in `destroy`/`create` and their row is carried verbatim;
/// * shrinking apps release `current − target` containers **in place**,
///   cheapest first (rows on the slackest servers go first, keeping tight
///   servers tightly packed);
/// * growing apps add `target − current` containers via the slack-indexed
///   best fit without disturbing their existing row;
/// * if any grow cannot fit, the round **falls back** to the full
///   [`place`] BFD re-pack (reported via [`Placement::delta_path`] =
///   false); if that also fails, `None` — exactly the full path's
///   contract, so callers retry with reduced counts either way.
///
/// Shrinks strictly precede grows, so capacity released by one app is
/// available to every grower in the same round.
pub fn place_delta(
    inputs: &[PlacementInput],
    capacities: &[Res],
    state: &mut PackState,
) -> Option<Placement> {
    let m = capacities.first().map(|c| c.m()).unwrap_or(0);
    let caps_bits = caps_sig(capacities);
    let total_cap = capacities.iter().fold(Res::zeros(m), |mut acc, c| {
        acc += c;
        acc
    });

    // Re-base the persistent state on reality.  A rebuild can only fail on
    // current rows that exceed capacity — `place` ignores mover rows, so
    // give it the final word rather than failing outright.
    if !state.ready || state.caps_bits != caps_bits {
        if state.rebuild(inputs, capacities, caps_bits).is_none() {
            return fallback_full(inputs, capacities, state);
        }
    } else if state.reconcile(inputs).is_none() {
        // reconcile anomaly (e.g. out-of-band moves that no longer fit the
        // incremental books): one exact rebuild decides corrupt-vs-fine
        if state.rebuild(inputs, capacities, caps_bits).is_none() {
            return fallback_full(inputs, capacities, state);
        }
    }

    let mut destroy: Vec<(AppId, ServerId, u32)> = Vec::new();
    let mut create: Vec<(AppId, ServerId, u32)> = Vec::new();
    let mut grows: Vec<(usize, u32)> = Vec::new(); // (input idx, current total)

    // Shrinks first: released capacity serves every grower below.
    for (idx, inp) in inputs.iter().enumerate() {
        let cur: u32 = inp.current.values().sum();
        if inp.target < cur {
            // the reconcile above pinned tracked row == inp.current, so the
            // current row is the authoritative source to release from
            let mut rows: Vec<(ServerId, u32)> =
                inp.current.iter().map(|(&s, &c)| (s, c)).collect();
            // release where servers are slackest (tie: lowest id) — the
            // cheapest containers to give up for packing quality
            rows.sort_by(|a, b| {
                let sa = state.free[a.0 .0].dominant_share(&total_cap);
                let sb = state.free[b.0 .0].dominant_share(&total_cap);
                sb.total_cmp(&sa).then(a.0 .0.cmp(&b.0 .0))
            });
            let t = state
                .tracked
                .get_mut(&inp.app)
                .expect("reconciled: shrinking app has a tracked row");
            let mut need = cur - inp.target;
            for (sid, have) in rows {
                if need == 0 {
                    break;
                }
                let take = need.min(have);
                let left = have - take;
                if left == 0 {
                    t.row.remove(&sid);
                } else {
                    t.row.insert(sid, left);
                }
                state.free[sid.0] += &inp.demand.times(take);
                destroy.push((inp.app, sid, take));
                need -= take;
            }
            debug_assert_eq!(need, 0, "tracked row must cover the shrink");
            if inp.target == 0 {
                state.tracked.remove(&inp.app);
            }
        } else if inp.target > cur {
            grows.push((idx, cur));
        }
    }

    // Grows best-fit-decreasing by dominant demand (the full path's order).
    grows.sort_by(|&(a, _), &(b, _)| {
        let da = inputs[a].demand.dominant_share(&total_cap);
        let db = inputs[b].demand.dominant_share(&total_cap);
        db.total_cmp(&da).then(a.cmp(&b))
    });
    let spread = state.spread.clone();
    for (idx, cur) in grows {
        let inp = &inputs[idx];
        match fill_best_fit(
            &inp.demand,
            inp.target - cur,
            &mut state.free,
            &total_cap,
            spread.as_ref(),
        ) {
            Some(extra) => {
                let t = state.tracked.entry(inp.app).or_insert_with(|| Tracked {
                    demand: inp.demand.clone(),
                    row: BTreeMap::new(),
                });
                for (&sid, &cnt) in &extra {
                    *t.row.entry(sid).or_insert(0) += cnt;
                    create.push((inp.app, sid, cnt));
                }
            }
            None => {
                // Delta packing failed — full BFD re-pack fallback.  The
                // in-place shrinks above are an abandoned plan; the next
                // call's reconcile patches them back from ground truth.
                return fallback_full(inputs, capacities, state);
            }
        }
    }

    // Commit: snapshot the full assignment for the decision.
    let mut assignment: Assignment = BTreeMap::new();
    for inp in inputs {
        let row = state
            .tracked
            .get(&inp.app)
            .map(|t| t.row.clone())
            .unwrap_or_default();
        assignment.insert(inp.app, row);
    }
    state.since_sync += 1;
    if state.since_sync >= RESYNC_EVERY {
        state.resync_free(capacities);
    }
    Some(Placement {
        assignment: Arc::new(assignment),
        destroy,
        create,
        delta_path: true,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::Rng;

    fn inp(id: u64, demand: Res, target: u32, current: &[(usize, u32)]) -> PlacementInput {
        PlacementInput {
            app: AppId(id),
            demand,
            target,
            current: current
                .iter()
                .map(|&(j, c)| (ServerId(j), c))
                .collect(),
        }
    }

    /// Per-server usage of `p` must fit `caps`, and every app must hold
    /// exactly its target.
    fn assert_valid(p: &Placement, inputs: &[PlacementInput], caps: &[Res]) {
        let m = caps.first().map(|c| c.m()).unwrap_or(0);
        for (j, cap) in caps.iter().enumerate() {
            let mut used = Res::zeros(m);
            for inpt in inputs {
                if let Some(cnt) = p.assignment[&inpt.app].get(&ServerId(j)) {
                    used += &inpt.demand.times(*cnt);
                }
            }
            assert!(used.fits_in(cap), "server {j} over capacity: {used:?}");
        }
        for inpt in inputs {
            let got: u32 = p.assignment[&inpt.app].values().sum();
            assert_eq!(got, inpt.target, "{:?} wrong total", inpt.app);
        }
    }

    #[test]
    fn packs_simple_case() {
        let caps = vec![Res(vec![4.0]), Res(vec![4.0])];
        let p = place(
            &[inp(1, Res(vec![1.0]), 6, &[])],
            &caps,
        )
        .unwrap();
        let total: u32 = p.assignment[&AppId(1)].values().sum();
        assert_eq!(total, 6);
    }

    #[test]
    fn pinned_apps_do_not_move() {
        let caps = vec![Res(vec![4.0]), Res(vec![4.0])];
        let p = place(
            &[
                inp(1, Res(vec![1.0]), 2, &[(0, 2)]), // unchanged
                inp(2, Res(vec![1.0]), 3, &[(1, 1)]), // grows
            ],
            &caps,
        )
        .unwrap();
        assert_eq!(p.assignment[&AppId(1)][&ServerId(0)], 2);
        assert!(p.adjusted_apps() == vec![AppId(2)]);
        // the delta is netted: app2's re-pack keeps its container on
        // server 1, so only the two new containers appear — no
        // destroy+create pair for the position that did not change
        assert!(p.destroy.is_empty(), "no-op deltas must be netted: {:?}", p.destroy);
        let created: u32 = p.create.iter().map(|&(_, _, c)| c).sum();
        assert_eq!(created, 2);
        assert_eq!(p.assignment[&AppId(2)][&ServerId(1)], 1, "kept container stays");
    }

    #[test]
    fn mover_landing_in_place_emits_no_deltas() {
        // app shrinks 3 -> 3? no: unchanged-count apps are pinned.  The
        // netting case: a mover whose re-pack lands exactly where it was.
        // One app alone, count changes 2 -> 2 is pinned, so use 2 -> 3 on
        // one server: destroy must be empty and create only the extra one.
        let caps = vec![Res(vec![8.0])];
        let p = place(&[inp(1, Res(vec![1.0]), 3, &[(0, 2)])], &caps).unwrap();
        assert!(p.destroy.is_empty(), "{:?}", p.destroy);
        assert_eq!(p.create, vec![(AppId(1), ServerId(0), 1)]);
        assert_eq!(p.moved_containers(), 1);
    }

    #[test]
    fn infeasible_returns_none() {
        let caps = vec![Res(vec![2.0])];
        assert!(place(&[inp(1, Res(vec![1.0]), 3, &[])], &caps).is_none());
    }

    #[test]
    fn fragmentation_case_needs_bfd() {
        // two servers 3+3; apps: one 2-demand x1, one 1-demand x4.
        // BFD places the big one first, then fills: feasible.
        let caps = vec![Res(vec![3.0]), Res(vec![3.0])];
        let p = place(
            &[
                inp(1, Res(vec![2.0]), 1, &[]),
                inp(2, Res(vec![1.0]), 4, &[]),
            ],
            &caps,
        )
        .unwrap();
        let t1: u32 = p.assignment[&AppId(1)].values().sum();
        let t2: u32 = p.assignment[&AppId(2)].values().sum();
        assert_eq!((t1, t2), (1, 4));
    }

    #[test]
    fn gpu_containers_land_on_gpu_servers() {
        let caps = vec![
            Res::cpu_gpu_ram(12.0, 1.0, 128.0),
            Res::cpu_gpu_ram(12.0, 0.0, 128.0),
        ];
        let p = place(
            &[inp(1, Res::cpu_gpu_ram(4.0, 1.0, 16.0), 1, &[])],
            &caps,
        )
        .unwrap();
        assert_eq!(p.assignment[&AppId(1)][&ServerId(0)], 1);
    }

    #[test]
    fn delta_grow_keeps_existing_row() {
        let caps = vec![Res(vec![4.0]), Res(vec![4.0])];
        let mut st = PackState::default();
        let inputs = [inp(1, Res(vec![1.0]), 3, &[(0, 2)])];
        let p = place_delta(&inputs, &caps, &mut st).unwrap();
        assert!(p.delta_path);
        assert!(st.is_warm());
        assert!(p.destroy.is_empty());
        assert_eq!(p.moved_containers(), 1, "grow moves only the new container");
        assert_eq!(p.assignment[&AppId(1)][&ServerId(0)], 3, "grows in place");
        assert_valid(&p, &inputs, &caps);
    }

    #[test]
    fn delta_shrink_releases_cheapest_in_place() {
        // app holds 2+2 across both servers; server 1 also hosts a pinned
        // neighbour, so server 0 is slacker — the shrink must release there
        let caps = vec![Res(vec![4.0]), Res(vec![4.0])];
        let mut st = PackState::default();
        let inputs = [
            inp(1, Res(vec![1.0]), 2, &[(0, 2), (1, 2)]),
            inp(2, Res(vec![2.0]), 1, &[(1, 1)]), // pinned neighbour
        ];
        let p = place_delta(&inputs, &caps, &mut st).unwrap();
        assert!(p.delta_path);
        assert!(p.create.is_empty(), "shrink creates nothing");
        assert_eq!(p.destroy, vec![(AppId(1), ServerId(0), 2)]);
        assert_eq!(p.assignment[&AppId(1)].get(&ServerId(0)), None);
        assert_eq!(p.assignment[&AppId(1)][&ServerId(1)], 2);
        assert_valid(&p, &inputs, &caps);
    }

    #[test]
    fn delta_falls_back_to_full_repack_on_fragmentation() {
        // B's scattered row {s0:1, s1:1} blocks A's 4-wide container; the
        // delta grow cannot fit it, but the full re-pack consolidates B
        // onto s1+s2 and frees s0.
        let caps = vec![Res(vec![4.0]), Res(vec![4.0]), Res(vec![2.0])];
        let mut st = PackState::default();
        let inputs = [
            inp(1, Res(vec![4.0]), 1, &[]),             // A: new, needs 4
            inp(2, Res(vec![2.0]), 3, &[(0, 1), (1, 1)]), // B: grows 2 -> 3
        ];
        let p = place_delta(&inputs, &caps, &mut st).unwrap();
        assert!(!p.delta_path, "must report the full re-pack fallback");
        assert_valid(&p, &inputs, &caps);
        // the state adopted the re-pack: a repeat call is a clean no-op
        let inputs2 = [
            inp(1, Res(vec![4.0]), 1, &[(0, 1)]),
            {
                let mut i = inp(2, Res(vec![2.0]), 3, &[]);
                i.current = p.assignment[&AppId(2)].clone();
                i
            },
        ];
        let p2 = place_delta(&inputs2, &caps, &mut st).unwrap();
        assert!(p2.delta_path);
        assert_eq!(p2.moved_containers(), 0, "nothing changed, nothing moves");
    }

    #[test]
    fn delta_departed_app_releases_capacity() {
        let caps = vec![Res(vec![4.0])];
        let mut st = PackState::default();
        let round1 = [
            inp(1, Res(vec![2.0]), 2, &[(0, 2)]),
            inp(2, Res(vec![1.0]), 0, &[]),
        ];
        place_delta(&round1, &caps, &mut st).unwrap();
        // app 1 completed; app 2 can now take the whole server
        let round2 = [inp(2, Res(vec![1.0]), 4, &[])];
        let p = place_delta(&round2, &caps, &mut st).unwrap();
        assert!(p.delta_path);
        assert_eq!(p.assignment[&AppId(2)][&ServerId(0)], 4);
    }

    #[test]
    fn delta_capacity_change_forces_rebuild() {
        let mut st = PackState::default();
        let inputs = [inp(1, Res(vec![1.0]), 2, &[])];
        place_delta(&inputs, &[Res(vec![4.0])], &mut st).unwrap();
        // the cluster shrank: the state must rebuild, not reuse stale free
        let p = place_delta(
            &[inp(1, Res(vec![1.0]), 2, &[(0, 2)])],
            &[Res(vec![2.0])],
            &mut st,
        )
        .unwrap();
        assert_eq!(p.moved_containers(), 0);
        assert!(place_delta(
            &[inp(1, Res(vec![1.0]), 3, &[(0, 2)])],
            &[Res(vec![2.0])],
            &mut st,
        )
        .is_none());
    }

    #[test]
    fn prop_placement_respects_capacity() {
        prop::check(150, |rng: &mut Rng| {
            let m = 2;
            let nsrv = rng.range_u64(1, 6) as usize;
            let caps: Vec<Res> = (0..nsrv)
                .map(|_| Res((0..m).map(|_| rng.range_f64(4.0, 20.0)).collect()))
                .collect();
            let napps = rng.range_u64(1, 6) as usize;
            let inputs: Vec<PlacementInput> = (0..napps)
                .map(|i| PlacementInput {
                    app: AppId(i as u64),
                    demand: Res((0..m).map(|_| rng.range_f64(0.5, 4.0)).collect()),
                    target: rng.range_u64(0, 6) as u32,
                    current: BTreeMap::new(),
                })
                .collect();
            if let Some(p) = place(&inputs, &caps) {
                // per-server usage within capacity
                for (j, cap) in caps.iter().enumerate() {
                    let mut used = Res::zeros(m);
                    for inpt in &inputs {
                        if let Some(cnt) = p.assignment[&inpt.app].get(&ServerId(j)) {
                            used += &inpt.demand.times(*cnt);
                        }
                    }
                    if !used.fits_in(cap) {
                        return Err(format!("server {j} over capacity"));
                    }
                }
                // every app got exactly its target
                for inpt in &inputs {
                    let got: u32 = p.assignment[&inpt.app].values().sum();
                    if got != inpt.target {
                        return Err(format!("{:?}: got {got} wanted {}", inpt.app, inpt.target));
                    }
                }
            }
            Ok(())
        });
    }

    /// The satellite property: `place_delta` ≡ `place` on feasibility and
    /// capacity invariants; pinned apps never appear in destroy/create;
    /// the delta path never moves more containers than the full re-pack.
    #[test]
    fn prop_delta_matches_full_repack() {
        prop::check(150, |rng: &mut Rng| {
            let m = 2;
            let nsrv = rng.range_u64(1, 6) as usize;
            let caps: Vec<Res> = (0..nsrv)
                .map(|_| Res((0..m).map(|_| rng.range_f64(6.0, 20.0)).collect()))
                .collect();
            let napps = rng.range_u64(1, 6) as usize;
            // round 1 (cold): establishes a committed placement
            let round1: Vec<PlacementInput> = (0..napps)
                .map(|i| PlacementInput {
                    app: AppId(i as u64),
                    demand: Res((0..m).map(|_| rng.range_f64(0.5, 3.0)).collect()),
                    target: rng.range_u64(0, 5) as u32,
                    current: BTreeMap::new(),
                })
                .collect();
            let Some(base) = place(&round1, &caps) else {
                return Ok(());
            };
            // round 2: grow/shrink/keep each app at random, from the
            // committed placement
            let round2: Vec<PlacementInput> = round1
                .iter()
                .map(|i| {
                    let cur = base.assignment[&i.app].clone();
                    let cur_total: u32 = cur.values().sum();
                    let target = match rng.below(4) {
                        0 => cur_total,                               // pinned
                        1 => cur_total.saturating_sub(rng.range_u64(1, 3) as u32),
                        _ => cur_total + rng.range_u64(0, 4) as u32, // grow
                    };
                    PlacementInput {
                        app: i.app,
                        demand: i.demand.clone(),
                        target,
                        current: cur,
                    }
                })
                .collect();

            let full = place(&round2, &caps);
            let mut st = PackState::default();
            let _ = place_delta(&round1, &caps, &mut st); // warm the state
            let delta = place_delta(&round2, &caps, &mut st);

            match (full, delta) {
                (Some(f), Some(d)) => {
                    // both feasible: validate capacity + exact targets
                    for p in [&f, &d] {
                        for (j, cap) in caps.iter().enumerate() {
                            let mut used = Res::zeros(m);
                            for i in &round2 {
                                if let Some(c) = p.assignment[&i.app].get(&ServerId(j)) {
                                    used += &i.demand.times(*c);
                                }
                            }
                            if !used.fits_in(cap) {
                                return Err(format!("server {j} over capacity"));
                            }
                        }
                        for i in &round2 {
                            let got: u32 = p.assignment[&i.app].values().sum();
                            if got != i.target {
                                return Err(format!("{:?} wrong total", i.app));
                            }
                        }
                    }
                    // pinned apps never show up in either delta list
                    for i in &round2 {
                        let cur_total: u32 = i.current.values().sum();
                        if i.target == cur_total {
                            let touched = d
                                .destroy
                                .iter()
                                .chain(d.create.iter())
                                .any(|&(a, _, _)| a == i.app);
                            if touched {
                                return Err(format!("pinned {:?} moved", i.app));
                            }
                            if d.assignment[&i.app] != i.current {
                                return Err(format!("pinned {:?} row changed", i.app));
                            }
                        }
                    }
                    // delta packing never moves more than the full re-pack
                    if d.delta_path && d.moved_containers() > f.moved_containers() {
                        return Err(format!(
                            "delta moved {} > full {}",
                            d.moved_containers(),
                            f.moved_containers()
                        ));
                    }
                    Ok(())
                }
                (None, Some(d)) if d.delta_path => {
                    // a genuine delta win (in-place rows dodge the
                    // fragmentation that killed the re-pack): still must
                    // be capacity-feasible at the exact targets
                    for (j, cap) in caps.iter().enumerate() {
                        let mut used = Res::zeros(m);
                        for i in &round2 {
                            if let Some(c) = d.assignment[&i.app].get(&ServerId(j)) {
                                used += &i.demand.times(*c);
                            }
                        }
                        if !used.fits_in(cap) {
                            return Err(format!("delta-win server {j} over capacity"));
                        }
                    }
                    Ok(())
                }
                (None, Some(_)) => Err("fallback succeeded where full place failed".into()),
                (Some(_), None) => {
                    Err("delta failed where full place succeeded (fallback broken)".into())
                }
                (None, None) => Ok(()),
            }
        });
    }

    #[test]
    fn spread_prefers_low_risk_domain_at_equal_slack() {
        // four empty identical servers: every choice is an equal-slack tie.
        // Risk-blind best fit takes the lowest index (server 0); the
        // spread tie-break must steer to the zero-risk domain instead.
        let caps = vec![Res(vec![4.0]); 4];
        let ctx = SpreadCtx { domain_of: vec![0, 0, 1, 1], risk: vec![1.0, 0.0] };
        let inputs = [inp(1, Res(vec![3.0]), 1, &[])];
        let blind = place(&inputs, &caps).unwrap();
        assert_eq!(blind.assignment[&AppId(1)][&ServerId(0)], 1);
        let aware = place_spread(&inputs, &caps, Some(&ctx)).unwrap();
        assert_eq!(aware.assignment[&AppId(1)].get(&ServerId(0)), None);
        assert_eq!(aware.assignment[&AppId(1)][&ServerId(2)], 1);
        // totals identical either way
        let t: u32 = aware.assignment[&AppId(1)].values().sum();
        assert_eq!(t, 1);
    }

    #[test]
    fn spread_distributes_an_app_across_domains_at_equal_risk() {
        // demand 3 on capacity 4: one container per server, so the second
        // container always faces an equal-slack tie among empty servers.
        // Risk-blind packs {s0, s1} (lowest indices, same rack); the
        // spread term must put the second container in the other rack.
        let caps = vec![Res(vec![4.0]); 4];
        let ctx = SpreadCtx { domain_of: vec![0, 0, 1, 1], risk: vec![0.0, 0.0] };
        let inputs = [inp(1, Res(vec![3.0]), 2, &[])];
        let blind = place(&inputs, &caps).unwrap();
        assert_eq!(blind.assignment[&AppId(1)][&ServerId(0)], 1);
        assert_eq!(blind.assignment[&AppId(1)][&ServerId(1)], 1);
        let aware = place_spread(&inputs, &caps, Some(&ctx)).unwrap();
        assert_eq!(aware.assignment[&AppId(1)][&ServerId(0)], 1);
        assert_eq!(aware.assignment[&AppId(1)][&ServerId(2)], 1, "spread to rack 1");
    }

    #[test]
    fn zero_risk_single_domain_spread_is_byte_identical() {
        // degenerate context (one domain, no risk evidence): the key
        // reduces to (slack, index) and the assignment must be identical
        let caps = vec![Res(vec![6.0]), Res(vec![4.0]), Res(vec![8.0])];
        let ctx = SpreadCtx { domain_of: vec![0, 0, 0], risk: vec![0.0] };
        let inputs = [
            inp(1, Res(vec![2.0]), 3, &[]),
            inp(2, Res(vec![1.0]), 5, &[]),
            inp(3, Res(vec![3.0]), 2, &[]),
        ];
        let blind = place(&inputs, &caps).unwrap();
        let aware = place_spread(&inputs, &caps, Some(&ctx)).unwrap();
        assert_eq!(blind.assignment, aware.assignment);
        assert_eq!(blind.destroy, aware.destroy);
        assert_eq!(blind.create, aware.create);
    }

    #[test]
    fn delta_state_spread_survives_invalidation_and_steers_grows() {
        let caps = vec![Res(vec![4.0]); 4];
        let mut st = PackState::default();
        st.set_spread(Some(SpreadCtx {
            domain_of: vec![0, 0, 1, 1],
            risk: vec![1.0, 0.0],
        }));
        st.invalidate();
        assert!(st.spread().is_some(), "risk context outlives the books");
        let inputs = [inp(1, Res(vec![3.0]), 1, &[])];
        let p = place_delta(&inputs, &caps, &mut st).unwrap();
        assert_eq!(p.assignment[&AppId(1)][&ServerId(2)], 1, "grow avoids risky rack");
    }

    /// The acceptance-criteria differential property: a spread context
    /// changes only container *placement*, never allocation totals or
    /// feasibility, vs. today's risk-blind solver.  Run at m = 1, where
    /// equal slack ⇔ equal free capacity, so equal-slack servers are
    /// provably interchangeable and the claim is exact (multi-dim cases
    /// are pinned by the deterministic tests above).
    #[test]
    fn prop_spread_changes_placement_never_totals() {
        prop::check(150, |rng: &mut Rng| {
            let nsrv = rng.range_u64(1, 8) as usize;
            let caps: Vec<Res> = (0..nsrv)
                .map(|_| Res(vec![rng.range_f64(4.0, 20.0)]))
                .collect();
            let napps = rng.range_u64(1, 6) as usize;
            let inputs: Vec<PlacementInput> = (0..napps)
                .map(|i| PlacementInput {
                    app: AppId(i as u64),
                    demand: Res(vec![rng.range_f64(0.5, 4.0)]),
                    target: rng.range_u64(0, 6) as u32,
                    current: BTreeMap::new(),
                })
                .collect();
            let n_domains = rng.range_u64(1, 4) as usize;
            let ctx = SpreadCtx {
                domain_of: (0..nsrv).map(|j| j % n_domains).collect(),
                risk: (0..n_domains).map(|_| rng.range_f64(0.0, 1.0)).collect(),
            };
            let blind = place(&inputs, &caps);
            let aware = place_spread(&inputs, &caps, Some(&ctx));
            match (blind, aware) {
                (None, None) => Ok(()),
                (Some(b), Some(a)) => {
                    for i in &inputs {
                        let tb: u32 = b.assignment[&i.app].values().sum();
                        let ta: u32 = a.assignment[&i.app].values().sum();
                        if tb != ta {
                            return Err(format!(
                                "{:?}: blind total {tb} != aware total {ta}",
                                i.app
                            ));
                        }
                    }
                    // aware must still respect capacity
                    for (j, cap) in caps.iter().enumerate() {
                        let mut used = Res::zeros(1);
                        for i in &inputs {
                            if let Some(c) = a.assignment[&i.app].get(&ServerId(j)) {
                                used += &i.demand.times(*c);
                            }
                        }
                        if !used.fits_in(cap) {
                            return Err(format!("aware server {j} over capacity"));
                        }
                    }
                    Ok(())
                }
                (b, a) => Err(format!(
                    "feasibility diverged: blind {} aware {}",
                    b.is_some(),
                    a.is_some()
                )),
            }
        });
    }

    /// The indexed fill must be byte-identical to the reference
    /// per-container linear scan it replaced.
    #[test]
    fn prop_indexed_fill_matches_linear_scan() {
        fn linear_fill(
            demand: &Res,
            count: u32,
            free: &mut [Res],
            total_cap: &Res,
        ) -> Option<BTreeMap<ServerId, u32>> {
            let mut assigned: BTreeMap<ServerId, u32> = BTreeMap::new();
            for _ in 0..count {
                let mut best: Option<(usize, f64)> = None;
                for (j, f) in free.iter().enumerate() {
                    if demand.fits_in(f) {
                        let slack = f
                            .clone()
                            .saturating_sub(demand)
                            .dominant_share(total_cap);
                        match best {
                            Some((_, bs)) if bs <= slack => {}
                            _ => best = Some((j, slack)),
                        }
                    }
                }
                let j = best?.0;
                free[j] -= demand;
                *assigned.entry(ServerId(j)).or_insert(0) += 1;
            }
            Some(assigned)
        }

        prop::check(200, |rng: &mut Rng| {
            let m = rng.range_u64(1, 3) as usize;
            let nsrv = rng.range_u64(1, 8) as usize;
            let caps: Vec<Res> = (0..nsrv)
                .map(|_| Res((0..m).map(|_| rng.range_f64(2.0, 16.0)).collect()))
                .collect();
            let total = caps.iter().fold(Res::zeros(m), |mut a, c| {
                a += c;
                a
            });
            let demand = Res((0..m).map(|_| rng.range_f64(0.5, 4.0)).collect());
            let count = rng.range_u64(0, 12) as u32;
            let mut free_a = caps.clone();
            let mut free_b = caps.clone();
            let a = fill_best_fit(&demand, count, &mut free_a, &total, None);
            let b = linear_fill(&demand, count, &mut free_b, &total);
            if a != b {
                return Err(format!("indexed {a:?} != linear {b:?}"));
            }
            Ok(())
        });
    }
}

//! Placement round: map per-application container *counts* onto concrete
//! servers (the xᵢⱼ of P2), keeping unadjusted applications pinned.
//!
//! Eq. 3 counts an application as adjusted if **any** xᵢⱼ changes, so the
//! placement round must (a) leave apps whose count is unchanged exactly
//! where they are and (b) re-place adjusted apps by best-fit-decreasing on
//! the dominant share — the classic FFD/BFD bin-packing heuristic, which at
//! paper scale (uniform containers, 20 servers) packs whatever the
//! aggregate-capacity check admits; when it cannot, the optimizer retries
//! with reduced counts (see [`crate::optimizer`]).

use std::collections::BTreeMap;

use crate::app::AppId;
use crate::resources::Res;

use super::ServerId;

/// One application's placement request.
#[derive(Clone, Debug)]
pub struct PlacementInput {
    pub app: AppId,
    pub demand: Res,
    /// Target total containers (the optimizer's nᵢ).
    pub target: u32,
    /// Current placement (empty for new apps).
    pub current: BTreeMap<ServerId, u32>,
}

/// Result: per-app server assignment plus the create/destroy delta.
#[derive(Clone, Debug, Default)]
pub struct Placement {
    /// Final xᵢⱼ.
    pub assignment: BTreeMap<AppId, BTreeMap<ServerId, u32>>,
    /// Containers to destroy, per app per server (before creates).
    pub destroy: Vec<(AppId, ServerId, u32)>,
    /// Containers to create, per app per server.
    pub create: Vec<(AppId, ServerId, u32)>,
}

impl Placement {
    /// Apps whose placement changed (rᵢ = 1 in Eq. 3 terms).
    pub fn adjusted_apps(&self) -> Vec<AppId> {
        let mut apps: Vec<AppId> = self
            .destroy
            .iter()
            .chain(self.create.iter())
            .map(|&(a, _, _)| a)
            .collect();
        apps.sort();
        apps.dedup();
        apps
    }
}

/// Compute a placement for the given targets on servers with `capacity`.
///
/// Returns `None` if the targets cannot be packed (caller reduces counts
/// and retries).  Unchanged apps (target == current total) keep their exact
/// xᵢⱼ row; changed apps release all containers and are re-packed
/// best-fit-decreasing.
pub fn place(inputs: &[PlacementInput], capacities: &[Res]) -> Option<Placement> {
    let m = capacities.first().map(|c| c.m()).unwrap_or(0);
    let mut free: Vec<Res> = capacities.to_vec();

    // Phase 1: pin unchanged apps and subtract their usage.
    let mut out = Placement::default();
    let mut movers: Vec<&PlacementInput> = Vec::new();
    for inp in inputs {
        let cur_total: u32 = inp.current.values().sum();
        if cur_total == inp.target && inp.target > 0 {
            for (&sid, &cnt) in &inp.current {
                let need = inp.demand.times(cnt);
                if !need.fits_in(&free[sid.0]) {
                    // existing state exceeds capacity — corrupted input
                    return None;
                }
                free[sid.0] -= &need;
            }
            out.assignment.insert(inp.app, inp.current.clone());
        } else {
            movers.push(inp);
        }
    }

    // Phase 2: movers release everything...
    for inp in &movers {
        for (&sid, &cnt) in &inp.current {
            if cnt > 0 {
                out.destroy.push((inp.app, sid, cnt));
            }
        }
    }

    // ...and are re-packed best-fit-decreasing by dominant demand.
    let total_cap = capacities.iter().fold(Res::zeros(m), |mut acc, c| {
        acc += c;
        acc
    });
    let mut order: Vec<usize> = (0..movers.len()).collect();
    order.sort_by(|&a, &b| {
        let da = movers[a].demand.dominant_share(&total_cap);
        let db = movers[b].demand.dominant_share(&total_cap);
        db.total_cmp(&da)
    });

    for &idx in &order {
        let inp = movers[idx];
        let mut assigned: BTreeMap<ServerId, u32> = BTreeMap::new();
        for _ in 0..inp.target {
            // best fit: the feasible server with the least remaining
            // dominant-share slack after placing (packs tightly).
            let mut best: Option<(usize, f64)> = None;
            for (j, f) in free.iter().enumerate() {
                if inp.demand.fits_in(f) {
                    let slack = f
                        .clone()
                        .saturating_sub(&inp.demand)
                        .dominant_share(&total_cap);
                    match best {
                        Some((_, bs)) if bs <= slack => {}
                        _ => best = Some((j, slack)),
                    }
                }
            }
            let j = best?.0;
            free[j] -= &inp.demand;
            *assigned.entry(ServerId(j)).or_insert(0) += 1;
        }
        for (&sid, &cnt) in &assigned {
            out.create.push((inp.app, sid, cnt));
        }
        out.assignment.insert(inp.app, assigned);
    }

    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::Rng;

    fn inp(id: u64, demand: Res, target: u32, current: &[(usize, u32)]) -> PlacementInput {
        PlacementInput {
            app: AppId(id),
            demand,
            target,
            current: current
                .iter()
                .map(|&(j, c)| (ServerId(j), c))
                .collect(),
        }
    }

    #[test]
    fn packs_simple_case() {
        let caps = vec![Res(vec![4.0]), Res(vec![4.0])];
        let p = place(
            &[inp(1, Res(vec![1.0]), 6, &[])],
            &caps,
        )
        .unwrap();
        let total: u32 = p.assignment[&AppId(1)].values().sum();
        assert_eq!(total, 6);
    }

    #[test]
    fn pinned_apps_do_not_move() {
        let caps = vec![Res(vec![4.0]), Res(vec![4.0])];
        let p = place(
            &[
                inp(1, Res(vec![1.0]), 2, &[(0, 2)]), // unchanged
                inp(2, Res(vec![1.0]), 3, &[(1, 1)]), // grows
            ],
            &caps,
        )
        .unwrap();
        assert_eq!(p.assignment[&AppId(1)][&ServerId(0)], 2);
        assert!(p.adjusted_apps() == vec![AppId(2)]);
        // app2 released its old container and re-packed
        assert!(p.destroy.contains(&(AppId(2), ServerId(1), 1)));
    }

    #[test]
    fn infeasible_returns_none() {
        let caps = vec![Res(vec![2.0])];
        assert!(place(&[inp(1, Res(vec![1.0]), 3, &[])], &caps).is_none());
    }

    #[test]
    fn fragmentation_case_needs_bfd() {
        // two servers 3+3; apps: one 2-demand x1, one 1-demand x4.
        // BFD places the big one first, then fills: feasible.
        let caps = vec![Res(vec![3.0]), Res(vec![3.0])];
        let p = place(
            &[
                inp(1, Res(vec![2.0]), 1, &[]),
                inp(2, Res(vec![1.0]), 4, &[]),
            ],
            &caps,
        )
        .unwrap();
        let t1: u32 = p.assignment[&AppId(1)].values().sum();
        let t2: u32 = p.assignment[&AppId(2)].values().sum();
        assert_eq!((t1, t2), (1, 4));
    }

    #[test]
    fn gpu_containers_land_on_gpu_servers() {
        let caps = vec![
            Res::cpu_gpu_ram(12.0, 1.0, 128.0),
            Res::cpu_gpu_ram(12.0, 0.0, 128.0),
        ];
        let p = place(
            &[inp(1, Res::cpu_gpu_ram(4.0, 1.0, 16.0), 1, &[])],
            &caps,
        )
        .unwrap();
        assert_eq!(p.assignment[&AppId(1)][&ServerId(0)], 1);
    }

    #[test]
    fn prop_placement_respects_capacity() {
        prop::check(150, |rng: &mut Rng| {
            let m = 2;
            let nsrv = rng.range_u64(1, 6) as usize;
            let caps: Vec<Res> = (0..nsrv)
                .map(|_| Res((0..m).map(|_| rng.range_f64(4.0, 20.0)).collect()))
                .collect();
            let napps = rng.range_u64(1, 6) as usize;
            let inputs: Vec<PlacementInput> = (0..napps)
                .map(|i| PlacementInput {
                    app: AppId(i as u64),
                    demand: Res((0..m).map(|_| rng.range_f64(0.5, 4.0)).collect()),
                    target: rng.range_u64(0, 6) as u32,
                    current: BTreeMap::new(),
                })
                .collect();
            if let Some(p) = place(&inputs, &caps) {
                // per-server usage within capacity
                for (j, cap) in caps.iter().enumerate() {
                    let mut used = Res::zeros(m);
                    for inpt in &inputs {
                        if let Some(cnt) = p.assignment[&inpt.app].get(&ServerId(j)) {
                            used += &inpt.demand.times(*cnt);
                        }
                    }
                    if !used.fits_in(cap) {
                        return Err(format!("server {j} over capacity"));
                    }
                }
                // every app got exactly its target
                for inpt in &inputs {
                    let got: u32 = p.assignment[&inpt.app].values().sum();
                    if got != inpt.target {
                        return Err(format!("{:?}: got {got} wanted {}", inpt.app, inpt.target));
                    }
                }
            }
            Ok(())
        });
    }
}

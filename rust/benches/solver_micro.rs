//! Solver micro-benchmarks: the optimizer must decide well under the
//! paper's implied budget (sub-second per event; also ≪ the 430 ms/task
//! latency it criticizes Mesos for).  Tracks heuristic vs exact MILP
//! latency and the end-to-end allocate() (counts + placement) path at
//! paper scale (50 apps × 20 slaves).

#[path = "harness/mod.rs"]
mod harness;

use std::collections::BTreeMap;

use dorm::app::AppId;
use dorm::config::DormConfig;
use dorm::optimizer::{build_count_milp, OptApp, Optimizer, SolveMode};
use dorm::resources::Res;
use dorm::solver::heuristic::{heuristic_solve, CountApp, CountProblem};
use dorm::solver::{milp, MilpOptions};
use dorm::util::Rng;
use dorm::workload::table2_rows;

fn paper_scale_problem(napps: usize, rng: &mut Rng) -> CountProblem {
    let rows = table2_rows();
    let apps: Vec<CountApp> = (0..napps)
        .map(|_| {
            let row = &rows[rng.below(rows.len() as u64) as usize];
            CountApp {
                demand: row.demand.clone(),
                weight: row.weight as f64,
                n_min: row.n_min,
                n_max: row.n_max,
                prev: (rng.f64() < 0.7).then(|| rng.range_u64(1, 8) as u32),
            }
        })
        .collect();
    CountProblem::new(apps, Res::cpu_gpu_ram(240.0, 5.0, 2560.0), 0.1, 0.1)
}

fn opt_apps(p: &CountProblem) -> Vec<OptApp> {
    p.apps
        .iter()
        .enumerate()
        .map(|(i, a)| OptApp {
            id: AppId(i as u64),
            demand: a.demand.clone(),
            weight: a.weight,
            n_min: a.n_min,
            n_max: a.n_max,
            prev: a.prev,
            current: BTreeMap::new(),
        })
        .collect()
}

fn main() {
    harness::banner("solver microbenchmarks (paper scale: 20 slaves, 240/5/2560)");
    let mut rng = Rng::new(3);

    for napps in [5usize, 15, 30, 50] {
        let p = paper_scale_problem(napps, &mut rng);
        harness::bench_micro(
            &format!("heuristic_solve, {napps} apps"),
            3,
            30,
            || {
                let _ = heuristic_solve(&p);
            },
        );
    }

    for napps in [5usize, 10, 15] {
        let p = paper_scale_problem(napps, &mut rng);
        let warm = heuristic_solve(&p);
        harness::bench_micro(
            &format!("exact MILP (B&B, warm-started), {napps} apps"),
            1,
            5,
            || {
                let m = build_count_milp(&p);
                let _ = milp::solve(
                    &m,
                    &MilpOptions {
                        warm_start: warm
                            .as_ref()
                            .map(|c| dorm::optimizer::counts_to_point(&p, c)),
                        ..Default::default()
                    },
                );
            },
        );
    }

    // end-to-end allocate(): counts + placement on 20 servers
    let caps: Vec<Res> = (0..20)
        .map(|i| Res::cpu_gpu_ram(12.0, if i < 5 { 1.0 } else { 0.0 }, 128.0))
        .collect();
    for napps in [15usize, 50] {
        let p = paper_scale_problem(napps, &mut rng);
        let apps = opt_apps(&p);
        let opt = Optimizer::with_mode(DormConfig::DORM3, SolveMode::Heuristic);
        let (mean, _, _) = harness::bench_micro(
            &format!("optimizer.allocate (counts+placement), {napps} apps"),
            3,
            20,
            || {
                let _ = opt.allocate(&apps, &caps);
            },
        );
        harness::paper_row(
            &format!("allocation decision latency, {napps} apps"),
            "sub-second (CPLEX)",
            &format!("{:.2} ms", mean / 1000.0),
        );
    }

    // warm-start incumbent reuse: the engine feeds the previous solution's
    // counts into the next (perturbed) solve; in exact mode that incumbent
    // bounds branch-and-bound from node 0 instead of waiting for an
    // integral leaf found from the per-call heuristic alone.  CPU-bound
    // rows only (LR/MF/CaffeNet): the uniform 7-row sample can push the
    // GPU n_min floors past the 5-GPU testbed and make the base infeasible.
    harness::banner("warm-started re-solve (previous counts as incumbent)");
    let rows = table2_rows();
    let cpu_apps: Vec<CountApp> = (0..10)
        .map(|_| {
            let row = &rows[rng.below(3) as usize];
            CountApp {
                demand: row.demand.clone(),
                weight: row.weight as f64,
                n_min: row.n_min,
                n_max: row.n_max,
                prev: (rng.f64() < 0.7).then(|| rng.range_u64(1, 8) as u32),
            }
        })
        .collect();
    let p = CountProblem::new(cpu_apps, Res::cpu_gpu_ram(240.0, 5.0, 2560.0), 0.1, 0.1);
    let apps = opt_apps(&p);
    let exact = Optimizer::with_mode(DormConfig::DORM3, SolveMode::Exact);
    let cap = Res::cpu_gpu_ram(240.0, 5.0, 2560.0);
    let (base_counts, _) = exact.solve_counts(&apps, &cap).expect("base instance solvable");
    let warm: BTreeMap<AppId, u32> = apps
        .iter()
        .zip(&base_counts)
        .map(|(a, &c)| (a.id, c))
        .collect();
    // the next event: one arrival perturbs the instance
    let mut apps2 = apps.clone();
    apps2.push(OptApp {
        id: AppId(10_000),
        demand: table2_rows()[0].demand.clone(),
        weight: 1.0,
        n_min: 1,
        n_max: 8,
        prev: None,
        current: BTreeMap::new(),
    });
    let (mean_cold, _, _) = harness::bench_micro(
        "exact re-solve after arrival, cold",
        1,
        5,
        || {
            let _ = exact.solve_counts(&apps2, &cap);
        },
    );
    let (mean_warm, _, _) = harness::bench_micro(
        "exact re-solve after arrival, warm-started",
        1,
        5,
        || {
            let _ = exact.solve_counts_warm(&apps2, &cap, Some(&warm));
        },
    );
    let (cold_counts, cold_stats) = exact.solve_counts(&apps2, &cap).expect("solvable");
    let (warm_counts, warm_stats) =
        exact.solve_counts_warm(&apps2, &cap, Some(&warm)).expect("solvable");
    assert!(warm_stats.warm_start, "warm incumbent must be recorded");
    let p2 = CountProblem::new(
        apps2
            .iter()
            .map(|a| CountApp {
                demand: a.demand.clone(),
                weight: a.weight,
                n_min: a.n_min,
                n_max: a.n_max,
                prev: a.prev,
            })
            .collect(),
        cap.clone(),
        0.1,
        0.1,
    );
    assert!(
        p2.utilization(&warm_counts) >= p2.utilization(&cold_counts) - 1e-9,
        "warm start must not degrade the objective"
    );
    println!(
        "  B&B nodes: cold {} vs warm {}",
        cold_stats.bb_nodes, warm_stats.bb_nodes
    );
    harness::paper_row(
        "warm-started exact re-solve vs cold",
        "n/a (new in this repo)",
        &format!("{:.2}x latency", mean_cold / mean_warm.max(0.01)),
    );
}

//! Control-plane transports (DESIGN.md §9).
//!
//! [`crate::proto`] defines *what* travels; this module defines *how*:
//!
//! * [`ControlPlane`] — the one-method client interface.  Everything that
//!   drives a master (harnesses, slave agents, the `dorm ctl` CLI, the
//!   parity tests) programs against this trait and cannot tell the
//!   transports apart — that indistinguishability is pinned by
//!   `tests/transport_parity.rs`.
//! * [`LocalTransport`] — direct dispatch into an owned
//!   [`DormMaster`]: zero-copy, no serialization, preserves the
//!   in-process semantics every pre-existing test runs under.
//! * [`TcpTransport`] — std-only TCP client: length-prefixed frames
//!   ([`crate::proto::wire`]), version handshake on connect, typed error
//!   responses end-to-end.
//! * [`serve`] ([`server`]) — the master side of TCP: accept loop,
//!   per-connection handshake enforcement, arrival-time stamping, lease
//!   sweeping.  [`SlaveAgent`] ([`agent`]) is the standalone slave event
//!   loop that heartbeats over any transport and applies the master's
//!   reconciliation directives to its local container book.

mod agent;
mod server;

use std::net::TcpStream;
use std::time::Duration;

use anyhow::{bail, Context, Result};

pub use agent::{HeartbeatOutcome, SlaveAgent};
pub use server::{serve, ServerHandle};

use crate::config::NetConfig;
use crate::master::DormMaster;
use crate::proto::{wire, Request, Response, PROTO_MAJOR, PROTO_MINOR};

/// A client view of the control plane: send one [`Request`], get one
/// [`Response`].  `Err` is reserved for *transport* failures (connection
/// lost, frame undecodable); every semantic failure arrives in-band as
/// [`Response::Error`] so both transports surface identical values.
pub trait ControlPlane {
    fn call(&mut self, req: Request) -> Result<Response>;
}

/// Direct dispatch into an owned master — the zero-cost transport the
/// in-process tests and simulator harnesses use.
pub struct LocalTransport {
    master: DormMaster,
}

impl LocalTransport {
    pub fn new(master: DormMaster) -> Self {
        LocalTransport { master }
    }

    pub fn master(&self) -> &DormMaster {
        &self.master
    }

    pub fn master_mut(&mut self) -> &mut DormMaster {
        &mut self.master
    }

    pub fn into_master(self) -> DormMaster {
        self.master
    }
}

impl ControlPlane for LocalTransport {
    fn call(&mut self, req: Request) -> Result<Response> {
        Ok(self.master.dispatch(req))
    }
}

/// Std-only TCP client: length-prefixed frames plus the version handshake
/// (connect fails with the peer's typed rejection on a version mismatch).
pub struct TcpTransport {
    stream: TcpStream,
    max_frame: usize,
}

impl TcpTransport {
    /// Connect and handshake.  `cfg` supplies the frame-size limit and IO
    /// timeout (`io_timeout_ms = 0` blocks forever).
    pub fn connect(addr: &str, cfg: &NetConfig) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        stream.set_nodelay(true).ok();
        let timeout = (cfg.io_timeout_ms > 0).then(|| Duration::from_millis(cfg.io_timeout_ms));
        stream.set_read_timeout(timeout)?;
        stream.set_write_timeout(timeout)?;
        let mut t = TcpTransport { stream, max_frame: cfg.max_frame_bytes };
        match t.call(Request::Hello { major: PROTO_MAJOR, minor: PROTO_MINOR })? {
            Response::HelloAck { .. } => Ok(t),
            Response::Error(e) => bail!("handshake rejected by {addr}: {e}"),
            other => bail!("unexpected handshake response from {addr}: {other:?}"),
        }
    }
}

impl ControlPlane for TcpTransport {
    fn call(&mut self, req: Request) -> Result<Response> {
        let payload = wire::encode_request(&req);
        wire::write_frame(&mut self.stream, &payload, self.max_frame)
            .context("send request frame")?;
        let payload = wire::read_frame(&mut self.stream, self.max_frame)
            .context("receive response frame")?;
        let rsp = wire::decode_response(&payload).context("decode response")?;
        Ok(rsp)
    }
}

//! Linear / mixed-integer programming substrate.
//!
//! The paper solves its allocation problem **P2** (§IV-B) with CPLEX.
//! CPLEX is proprietary, so this module implements the needed solver stack
//! from scratch (DESIGN.md §1, S3–S5):
//!
//! * [`simplex`] — dense-tableau two-phase primal simplex for LP,
//! * [`milp`] — branch-and-bound over the LP relaxation for MILP,
//! * [`heuristic`] — DRF-guided greedy + local search used for large
//!   instances and as a warm-start incumbent for branch-and-bound; its
//!   quality is cross-validated against the exact solver in the tests and
//!   in `benches/solver_micro.rs`.

pub mod heuristic;
pub mod milp;
pub mod simplex;

pub use milp::{Milp, MilpOptions, MilpOutcome};
pub use simplex::{Cmp, Constraint, Lp, LpOutcome};

//! Simulation runner: drives a [`CmsPolicy`] over a workload trace,
//! tracking progress, adjustments and the §IV-A metrics.
//!
//! The runner owns the ground truth ([`crate::cluster::ClusterState`] +
//! per-app progress); policies only *decide* assignments, through the same
//! backend-neutral [`CmsPolicy`]/[`crate::sched::SchedCtx`] interface the
//! live master drives (`crate::sched`) — on every arrival/completion the
//! runner snapshots its state into [`crate::sched::SchedApp`] rows and
//! applies the returned update through create/destroy diffs so the
//! capacity invariants are checked on every event (`debug_assert` +
//! explicit check in tests).

use std::collections::BTreeMap;

use crate::app::AppId;
use crate::cluster::ClusterState;
use crate::config::{ClusterConfig, SimConfig};
use crate::drf::{drf_allocate, fairness_loss, DrfApp};
use crate::metrics::RunMetrics;
use crate::resources::Res;
use crate::sched::{CmsPolicy, SchedApp, SchedCtx};
use crate::workload::{Table2Row, WorkloadApp};

use super::engine::EventQueue;
use super::perf_model::PerfModel;

/// One application inside the simulation.
#[derive(Clone, Debug)]
pub struct SimApp {
    pub id: AppId,
    pub row: usize,
    pub tag: String,
    pub demand: Res,
    pub weight: f64,
    pub n_min: u32,
    pub n_max: u32,
    /// Static count the baseline policies use.
    pub baseline_n: u32,
    pub submit: f64,
    pub work_total: f64,
    pub work_remaining: f64,
    pub containers: u32,
    /// Last time progress was settled.
    pub last_settle: f64,
    /// No progress before this time (checkpoint/kill/resume pause).
    pub paused_until: f64,
    /// Times this app was killed+resumed (Fig. 9b bookkeeping).
    pub kills: u32,
    /// Completion-event version (lazy cancellation).
    pub version: u64,
    pub completed_at: Option<f64>,
}

impl SimApp {
    /// Settle progress up to `now` given the perf model.
    fn settle(&mut self, now: f64, pm: &PerfModel) {
        let start = self.last_settle.max(self.paused_until.min(now));
        // active interval is [max(last_settle, paused_until), now]
        let active_from = self.last_settle.max(self.paused_until);
        if now > active_from && self.containers > 0 {
            let dt = now - active_from;
            self.work_remaining =
                (self.work_remaining - dt * pm.speed(self.containers)).max(0.0);
        }
        let _ = start;
        self.last_settle = now;
    }

    /// Absolute completion time if the allocation stays as-is.
    fn eta(&self, now: f64, pm: &PerfModel) -> Option<f64> {
        if self.containers == 0 {
            return None;
        }
        let start = now.max(self.paused_until);
        Some(start + self.work_remaining / pm.speed(self.containers))
    }
}

#[derive(Clone, Debug, PartialEq)]
enum Event {
    Arrival(usize),
    Completion { app: AppId, version: u64 },
    Sample,
}

/// Everything a run produces.
pub struct SimOutcome {
    pub metrics: RunMetrics,
    /// All apps (completed and not) at horizon end.
    pub apps: BTreeMap<AppId, SimApp>,
    /// Completed fraction.
    pub completed: usize,
}

/// Run `policy` over `workload` on `cluster_cfg` for `sim.horizon_hours`.
pub fn run_sim(
    policy: &mut dyn CmsPolicy,
    rows: &[Table2Row],
    workload: &[WorkloadApp],
    cluster_cfg: &ClusterConfig,
    sim: &SimConfig,
    pm: &PerfModel,
) -> SimOutcome {
    let mut cluster = ClusterState::new(cluster_cfg);
    let mut metrics = RunMetrics::new(&policy.name());
    let mut q: EventQueue<Event> = EventQueue::new();
    let mut apps: BTreeMap<AppId, SimApp> = BTreeMap::new();
    let mut done: BTreeMap<AppId, SimApp> = BTreeMap::new();
    let mut total_adjusted = 0u32;

    for (i, w) in workload.iter().enumerate() {
        if w.submit_hours <= sim.horizon_hours {
            q.schedule(w.submit_hours, Event::Arrival(i));
        }
    }
    q.schedule(0.0, Event::Sample);

    while let Some(ev) = q.pop() {
        let now = ev.time;
        if now > sim.horizon_hours {
            break;
        }
        match ev.event {
            Event::Arrival(idx) => {
                let w = &workload[idx];
                let row = &rows[w.row];
                let id = AppId(idx as u64);
                let app = SimApp {
                    id,
                    row: w.row,
                    tag: w.tag.clone(),
                    demand: row.demand.clone(),
                    weight: row.weight as f64,
                    n_min: row.n_min,
                    n_max: row.n_max,
                    baseline_n: w.baseline_n,
                    submit: now,
                    work_total: pm.work_for(w.duration_at_baseline_hours, w.baseline_n),
                    work_remaining: pm.work_for(w.duration_at_baseline_hours, w.baseline_n),
                    containers: 0,
                    last_settle: now,
                    paused_until: now + policy.admission_latency_hours(),
                    kills: 0,
                    version: 0,
                    completed_at: None,
                };
                cluster.register_app(id, app.demand.clone());
                apps.insert(id, app);
                reallocate(policy, rows, &mut apps, &mut cluster, &mut q, now, pm,
                           &mut metrics, &mut total_adjusted);
                sample(&mut metrics, now, &apps, &cluster, total_adjusted);
            }
            Event::Completion { app: id, version } => {
                let Some(app) = apps.get_mut(&id) else { continue };
                if app.version != version {
                    continue; // stale event
                }
                app.settle(now, pm);
                debug_assert!(app.work_remaining <= 1e-6, "{}", app.work_remaining);
                app.completed_at = Some(now);
                metrics
                    .completions
                    .push((app.tag.clone(), now - app.submit));
                metrics
                    .app_durations
                    .insert(id.0, (app.tag.clone(), now - app.submit));
                let finished = apps.remove(&id).unwrap();
                cluster.remove_app(id);
                done.insert(id, finished);
                reallocate(policy, rows, &mut apps, &mut cluster, &mut q, now, pm,
                           &mut metrics, &mut total_adjusted);
                sample(&mut metrics, now, &apps, &cluster, total_adjusted);
            }
            Event::Sample => {
                sample(&mut metrics, now, &apps, &cluster, total_adjusted);
                let next = now + sim.sample_period_min / 60.0;
                if next <= sim.horizon_hours {
                    q.schedule(next, Event::Sample);
                }
            }
        }
    }

    // merge remaining active apps into the report
    let completed = done.len();
    for (id, app) in apps {
        done.insert(id, app);
    }
    SimOutcome { metrics, apps: done, completed }
}

/// Ask the policy for a new assignment and apply it.
#[allow(clippy::too_many_arguments)]
fn reallocate(
    policy: &mut dyn CmsPolicy,
    rows: &[Table2Row],
    apps: &mut BTreeMap<AppId, SimApp>,
    cluster: &mut ClusterState,
    q: &mut EventQueue<Event>,
    now: f64,
    pm: &PerfModel,
    metrics: &mut RunMetrics,
    total_adjusted: &mut u32,
) {
    // settle everyone before the allocation changes
    for app in apps.values_mut() {
        app.settle(now, pm);
    }
    // snapshot into the backend-neutral view the live master also produces
    let snapshot: BTreeMap<AppId, SchedApp> = apps
        .iter()
        .map(|(id, a)| {
            (
                *id,
                SchedApp {
                    id: *id,
                    demand: a.demand.clone(),
                    weight: a.weight,
                    n_min: a.n_min,
                    n_max: a.n_max,
                    containers: a.containers,
                    placement: cluster.placement_of(*id),
                    submit: a.submit,
                    baseline_n: a.baseline_n,
                    engine: rows[a.row].engine,
                },
            )
        })
        .collect();
    let capacities: Vec<Res> = cluster
        .servers
        .iter()
        .map(|s| s.capacity.clone())
        .collect();
    let update = {
        let ctx = SchedCtx { now, apps: &snapshot, capacities: &capacities };
        policy.on_change(&ctx)
    };
    let Some(update) = update else { return };

    // apply diffs: ALL destroys first (shrinking apps free the space the
    // growing ones move into), then all creates.
    let mut changed: Vec<AppId> = Vec::new();
    for (id, _) in apps.iter() {
        let target = update.assignment.get(id).cloned().unwrap_or_default();
        let current = cluster.placement_of(*id);
        if target == current {
            continue;
        }
        changed.push(*id);
        for (&sid, &cnt) in &current {
            cluster
                .destroy_containers(*id, sid, cnt)
                .expect("destroy within bookkeeping");
        }
    }
    for id in &changed {
        let target = update.assignment.get(id).cloned().unwrap_or_default();
        for (&sid, &cnt) in &target {
            if let Err(e) = cluster.create_containers(*id, sid, cnt) {
                panic!("policy {} produced invalid placement: {e}", policy.name());
            }
        }
        if let Some(app) = apps.get_mut(id) {
            app.containers = target.values().sum();
        }
    }

    // pauses + reschedules
    let adjusted: Vec<AppId> = update.adjusted.clone();
    for id in &adjusted {
        if let Some(app) = apps.get_mut(id) {
            app.paused_until = now + pm.adjust_pause_hours();
            app.kills += 1;
        }
    }
    if !adjusted.is_empty() {
        *total_adjusted += adjusted.len() as u32;
        metrics.adjustment_batch_sizes.push(adjusted.len() as u32);
    }
    for app in apps.values_mut() {
        app.version += 1;
        if let Some(eta) = app.eta(now, pm) {
            q.schedule(eta, Event::Completion { app: app.id, version: app.version });
        }
    }
    debug_assert!(cluster.check_invariants().is_ok());
}

/// Record the §IV-A metrics at `now`.
fn sample(
    metrics: &mut RunMetrics,
    now: f64,
    apps: &BTreeMap<AppId, SimApp>,
    cluster: &ClusterState,
    total_adjusted: u32,
) {
    metrics.utilization.push(now, cluster.utilization());
    // fairness loss (Eq. 2) over the active set
    let cap = cluster.total_capacity();
    let drf_apps: Vec<DrfApp> = apps
        .values()
        .map(|a| DrfApp {
            demand: a.demand.clone(),
            weight: a.weight,
            n_min: a.n_min.min(a.n_max),
            n_max: a.n_max,
        })
        .collect();
    let shat = if drf_apps.is_empty() {
        vec![]
    } else {
        drf_allocate(&drf_apps, &cap).shares
    };
    let actual: Vec<f64> = apps
        .values()
        .map(|a| a.demand.times(a.containers).dominant_share(&cap))
        .collect();
    metrics.fairness_loss.push(now, fairness_loss(&actual, &shat));
    metrics.adjustments.push(now, total_adjusted as f64);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::StaticPolicy;
    use crate::workload::{table2_rows, WorkloadGen};
    use crate::util::Rng;

    fn tiny_workload() -> (Vec<Table2Row>, Vec<WorkloadApp>) {
        let rows = table2_rows();
        let apps = vec![
            WorkloadApp { row: 0, tag: "LR".into(), submit_hours: 0.0,
                duration_at_baseline_hours: 2.0, baseline_n: 8 },
            WorkloadApp { row: 1, tag: "MF".into(), submit_hours: 0.5,
                duration_at_baseline_hours: 3.0, baseline_n: 8 },
        ];
        (rows, apps)
    }

    #[test]
    fn static_policy_runs_apps_at_fixed_duration() {
        let (rows, wl) = tiny_workload();
        let cfg = ClusterConfig::paper_testbed();
        let sim = SimConfig { horizon_hours: 12.0, ..Default::default() };
        let pm = PerfModel::default();
        let mut pol = StaticPolicy::new();
        let out = run_sim(&mut pol, &rows, &wl, &cfg, &sim, &pm);
        assert_eq!(out.completed, 2);
        // static baseline runs each app at exactly its baseline count ->
        // duration equals the sampled duration
        let lr_dur = out.metrics.completions.iter()
            .find(|(t, _)| t == "LR").unwrap().1;
        assert!((lr_dur - 2.0).abs() < 1e-6, "{lr_dur}");
    }

    #[test]
    fn full_table2_workload_static_completes_some() {
        let rows = table2_rows();
        let gen = WorkloadGen::default();
        let mut rng = Rng::new(5);
        let wl = gen.generate(&mut rng);
        let cfg = ClusterConfig::paper_testbed();
        let sim = SimConfig { horizon_hours: 24.0, ..Default::default() };
        let mut pol = StaticPolicy::new();
        let out = run_sim(&mut pol, &rows, &wl, &cfg, &sim, &pm_fast());
        assert!(out.completed > 0);
        // utilization sampled and bounded by m = 3
        assert!(out.metrics.utilization.max() <= 3.0 + 1e-9);
        assert!(out.metrics.utilization.max() > 0.0);
    }

    fn pm_fast() -> PerfModel {
        PerfModel::default()
    }
}

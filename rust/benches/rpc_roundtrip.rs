//! Control-plane RPC round-trip latency: `LocalTransport` vs
//! `TcpTransport` over loopback (DESIGN.md §9).
//!
//! Dorm's sharing-overhead argument (§III-D) depends on the master being
//! off the task hot path — apps only call it on submit/resize — so the
//! absolute numbers here are budget checks, not throughput goals: an
//! in-process dispatch should be microseconds, a loopback frame round
//! trip tens-to-hundreds of microseconds, and both are noise against the
//! paper's 430 ms *per-task* latency of two-level sharing (`dorm
//! latency`).  Three request shapes are timed: a lease-only heartbeat
//! (the steady-state packet), a heartbeat carrying a full `SlaveReport`
//! (encode/decode of the largest periodic payload), and `QueryState`
//! (the largest response payload).

#[path = "harness/mod.rs"]
mod harness;

use dorm::app::{AppSpec, CheckpointStore, Engine};
use dorm::config::{ClusterConfig, DormConfig, NetConfig};
use dorm::master::DormMaster;
use dorm::net::{serve, ControlPlane, LocalTransport, TcpTransport};
use dorm::proto::{wire, Request, Response};
use dorm::resources::Res;

fn master() -> DormMaster {
    let dir = std::env::temp_dir().join(format!("dorm_rpc_bench_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut m = DormMaster::new(
        &ClusterConfig::uniform(8, Res::cpu_gpu_ram(12.0, 0.0, 64.0)),
        DormConfig { theta1: 0.1, theta2: 0.1 },
        CheckpointStore::new(dir).unwrap(),
    );
    // a representative live population so QueryState/report payloads are
    // non-trivial: 6 running apps spread over the 8 servers
    for i in 0..6u32 {
        m.submit(AppSpec {
            executor: Engine::MxNet,
            demand: Res::cpu_gpu_ram(2.0, 0.0, 8.0),
            weight: 1 + (i % 3),
            n_max: 8,
            n_min: 1,
            cmd: ["lr".into(), "lr".into()],
        })
        .unwrap();
    }
    m
}

/// The three request shapes; the heartbeat report mirrors server 0's book
/// so reconciliation answers "converged" (no directives — steady state).
fn requests(m: &DormMaster) -> Vec<(&'static str, Request)> {
    let report = m.slaves[0].report();
    vec![
        ("heartbeat (lease only)", Request::Heartbeat {
            server: 0,
            now_hours: 1.0,
            report: None,
            acks: vec![],
        }),
        ("heartbeat + SlaveReport", Request::Heartbeat {
            server: 0,
            now_hours: 1.0,
            report: Some(report),
            acks: vec![],
        }),
        ("query state (full view)", Request::QueryState { app: None }),
    ]
}

fn drive(t: &mut dyn ControlPlane, label: &str, shapes: &[(&'static str, Request)], iters: u32) {
    for (name, req) in shapes {
        let req = req.clone();
        harness::bench_micro(&format!("{label}: {name}"), 50, iters, || {
            let rsp = t.call(req.clone()).expect("transport failure mid-bench");
            assert!(!matches!(rsp, Response::Error(_)), "{rsp:?}");
        });
    }
}

fn main() {
    harness::banner("control-plane RPC round trip (local dispatch vs loopback TCP)");

    let shapes = {
        let m = master();
        requests(&m)
    };
    for (name, req) in &shapes {
        println!(
            "  {:<44} request {} B, worst-case frame limit {} B",
            name,
            wire::encode_request(req).len(),
            NetConfig::default().max_frame_bytes,
        );
    }

    harness::banner("LocalTransport (direct dispatch, zero-copy)");
    let mut local = LocalTransport::new(master());
    drive(&mut local, "local", &shapes, 2000);

    harness::banner("TcpTransport (length-prefixed frames over 127.0.0.1)");
    let net = NetConfig { bind_addr: "127.0.0.1:0".into(), ..NetConfig::default() };
    let handle = serve(master(), &net).unwrap();
    let mut tcp = TcpTransport::connect(&handle.addr().to_string(), &net).unwrap();
    drive(&mut tcp, "tcp", &shapes, 1000);
    handle.stop();

    harness::banner("context");
    harness::paper_row(
        "per-task scheduling latency, two-level sharing",
        "~430 ms",
        "(see `dorm latency`)",
    );
    println!(
        "  Dorm's control plane is off the task path: tasks place locally\n\
         \x20 (microseconds); the RPCs above happen once per resize/beat."
    );
}

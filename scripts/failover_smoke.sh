#!/usr/bin/env bash
# Failover smoke test for the master HA subsystem (DESIGN.md §11):
# start an HA primary + a standby + one slave as real processes on
# 127.0.0.1, drive a workload through `dorm ctl`, `kill -9` the primary
# mid-workload, and assert that
#   * the standby promotes itself within the master lease,
#   * the slave re-dials the candidate list and stays converged,
#   * the post-takeover StateView matches the pre-kill view (same apps,
#     steps, checkpoints) at epoch+1, and
#   * a write routed to a deposed-epoch master is refused.
# Run from the repo root after `cargo build --release`; exits non-zero on
# any failed step.
set -euo pipefail

BIN=${BIN:-rust/target/release/dorm}
PORT_A=${PORT_A:-46021}   # primary
PORT_B=${PORT_B:-46022}   # standby
PORT_C=${PORT_C:-46023}   # "deposed primary" stand-in (old epoch)
ADDR_A=127.0.0.1:$PORT_A
ADDR_B=127.0.0.1:$PORT_B
ADDR_C=127.0.0.1:$PORT_C
STORE=$(mktemp -d)        # the shared "reliable storage system"
STORE_C=$(mktemp -d)
LOG=$(mktemp -d)
PRIMARY_PID=
STANDBY_PID=
SLAVE_PID=
DEPOSED_PID=

cleanup() {
  for pid in "$SLAVE_PID" "$PRIMARY_PID" "$STANDBY_PID" "$DEPOSED_PID"; do
    [ -n "$pid" ] && kill "$pid" 2>/dev/null || true
  done
  rm -rf "$STORE" "$STORE_C" "$LOG"
}
trap cleanup EXIT

fail() {
  echo "FAILOVER SMOKE FAIL: $1" >&2
  for f in primary standby slave deposed; do
    echo "--- $f log ---" >&2; cat "$LOG/$f.log" >&2 2>/dev/null || true
  done
  exit 1
}

# one control-plane request against the candidate list (ctl itself
# re-dials candidates and fences stale epochs)
ctl() {
  "$BIN" ctl --connect "$ADDR_A,$ADDR_B" "$@"
}

wait_for() { # wait_for <file> <pattern> <tries> <what>
  for _ in $(seq 1 "$3"); do
    grep -q "$2" "$1" 2>/dev/null && return 0
    sleep 0.1
  done
  fail "$4"
}

echo "== starting HA primary ($ADDR_A, 1 slave, snapshots every 4 events)"
"$BIN" master --bind "$ADDR_A" --slaves 1 --theta1 0.5 --theta2 0.5 \
  --store "$STORE" --ha --snapshot-every 4 >"$LOG/primary.log" 2>&1 &
PRIMARY_PID=$!
wait_for "$LOG/primary.log" "listening" 50 "primary never started listening"
grep -q "epoch 1" "$LOG/primary.log" || fail "primary should serve epoch 1"

echo "== starting standby ($ADDR_B, watching $ADDR_A, lease 1500 ms)"
"$BIN" master --standby --bind "$ADDR_B" --watch "$ADDR_A" --store "$STORE" \
  --master-lease-ms 1500 --probe-ms 150 --snapshot-every 4 \
  >"$LOG/standby.log" 2>&1 &
STANDBY_PID=$!
wait_for "$LOG/standby.log" "watching" 50 "standby never started watching"

echo "== starting slave agent with candidate list [$ADDR_A, $ADDR_B]"
"$BIN" slave --connect "$ADDR_A,$ADDR_B" --index 0 --period-ms 150 \
  >"$LOG/slave.log" 2>&1 &
SLAVE_PID=$!

echo "== drive workload: two apps, progress, a checkpoint past step 120"
ctl submit --cpu 2 --ram 8 --nmax 4 | grep -q "submitted app1" || fail "submit app1"
ctl submit --cpu 2 --ram 8 --nmax 2 | grep -q "submitted app2" || fail "submit app2"
ctl advance --app 1 --steps 120 | grep -q ok || fail "advance app1"
ctl checkpoint --app 1 | grep -q ok || fail "checkpoint app1"
ctl advance --app 1 --steps 30 | grep -q ok || fail "advance app1 past ckpt"
wait_for "$LOG/slave.log" "applied" 50 "slave never applied reconciliation directives"

PRE=$(ctl query)
echo "$PRE" | grep -q "epoch=1" || fail "pre-kill view should be epoch 1: $PRE"
echo "$PRE" | grep -q "app1 Running containers=4 steps=150 ckpt=120" \
  || fail "unexpected pre-kill app1 state: $PRE"
echo "$PRE" | grep -q "app2 Running containers=2" \
  || fail "unexpected pre-kill app2 state: $PRE"

echo "== kill -9 the primary mid-workload"
kill -9 "$PRIMARY_PID" || fail "could not kill primary"
PRIMARY_PID=

echo "== standby must promote within the lease"
wait_for "$LOG/standby.log" "promoted to epoch 2" 300 \
  "standby never promoted (lease 1500 ms)"

echo "== clients re-dial: post-takeover view matches pre-kill at epoch 2"
POST=
for _ in $(seq 1 100); do
  if POST=$("$BIN" ctl --connect "$ADDR_A,$ADDR_B" query 2>/dev/null); then
    break
  fi
  sleep 0.1
done
[ -n "$POST" ] || fail "no master reachable after takeover"
echo "$POST" | grep -q "epoch=2" || fail "post-takeover view should be epoch 2: $POST"
echo "$POST" | grep -q "app1 Running containers=4 steps=150 ckpt=120" \
  || fail "app1 state lost across takeover: $POST"
echo "$POST" | grep -q "app2 Running containers=2" \
  || fail "app2 state lost across takeover: $POST"

echo "== slave re-dials the standby and keeps reconciling"
wait_for "$LOG/slave.log" "connected to master $ADDR_B" 100 \
  "slave never re-dialed the standby"
# a post-takeover submit must flow through the promoted master to the
# slave's book (complete app2 first so the new app gets fresh creates)
ctl complete --app 2 | grep -q ok || fail "complete app2 via standby"
ctl submit --cpu 2 --ram 8 --nmax 2 | grep -q "submitted app3" \
  || fail "submit app3 via standby"
for _ in $(seq 1 50); do
  if ctl query | grep -q "app3 Running containers=2"; then break; fi
  sleep 0.1
done
ctl query | grep -q "app3 Running containers=2" \
  || fail "post-takeover submit did not run: $(ctl query)"

echo "== a deposed-epoch master's writes are refused"
"$BIN" master --bind "$ADDR_C" --slaves 1 --epoch 1 --store "$STORE_C" \
  >"$LOG/deposed.log" 2>&1 &
DEPOSED_PID=$!
wait_for "$LOG/deposed.log" "listening" 50 "deposed stand-in never started"
set +e
DEPOSED_OUT=$("$BIN" ctl --connect "$ADDR_C" --min-epoch 2 submit --cpu 2 --ram 8 2>&1)
DEPOSED_RC=$?
set -e
[ "$DEPOSED_RC" -ne 0 ] || fail "write to deposed epoch-1 master was accepted"
echo "$DEPOSED_OUT" | grep -qi "stale epoch" \
  || fail "expected a stale-epoch refusal, got: $DEPOSED_OUT"
# the same fence lets the promoted master through
"$BIN" ctl --connect "$ADDR_B" --min-epoch 2 query >/dev/null \
  || fail "epoch-2 master wrongly fenced"

echo "== shutdown: promoted master + deposed stand-in exit, slave drains"
"$BIN" ctl --connect "$ADDR_B" shutdown | grep -q ok || fail "standby shutdown"
"$BIN" ctl --connect "$ADDR_C" shutdown | grep -q ok || fail "deposed shutdown"
for _ in $(seq 1 100); do
  kill -0 "$STANDBY_PID" 2>/dev/null || break
  sleep 0.1
done
if kill -0 "$STANDBY_PID" 2>/dev/null; then
  fail "promoted master still running"
fi
STANDBY_PID=
DEPOSED_PID=
# the slave exits once every candidate stays unreachable
for _ in $(seq 1 200); do
  kill -0 "$SLAVE_PID" 2>/dev/null || break
  sleep 0.1
done
if kill -0 "$SLAVE_PID" 2>/dev/null; then
  fail "slave still running after masters left"
fi
SLAVE_PID=

echo "FAILOVER SMOKE PASS: kill -9 -> promote(epoch 2) -> re-dial -> fence all clean"

//! The versioned control-plane protocol (DESIGN.md §9).
//!
//! Dorm's core claim is *flat sharing overhead*: applications launch tasks
//! directly on their partitions and only talk to the master on resize
//! (§III-D), so the control plane is a narrow command protocol rather than
//! a wide object API.  This module pins that surface down as data:
//!
//! * [`Request`] / [`Response`] — every master↔slave and harness↔master
//!   interaction as a serializable message pair.  `DormMaster::dispatch`
//!   is the single entry point that consumes a [`Request`] and produces a
//!   [`Response`]; the legacy `pub fn` surface is a set of helpers behind
//!   it.
//! * [`ErrorCode`] / [`ProtoError`] — typed failures.  A transport error
//!   (bad frame, unknown tag) and a semantic error (unknown app, invalid
//!   state) travel in the same decodable envelope, so a remote peer never
//!   sees a hang or a closed socket where a diagnosis was possible.
//! * [`PROTO_MAJOR`] / [`PROTO_MINOR`] + [`negotiate`] — the version
//!   handshake.  Every connection opens with [`Request::Hello`]; a major
//!   mismatch (or a *newer* minor — the peer could send requests we
//!   cannot decode) is rejected with [`ErrorCode::VersionMismatch`].
//! * [`Directive`] — the master→slave half of the heartbeat exchange.
//!   Remote slaves converge on the master's book by reconciliation: each
//!   heartbeat carries the slave's [`SlaveReport`], and the ack returns
//!   the create/destroy directives that make the remote book match the
//!   master's (idempotent, self-healing against lost acks — the Borg/K8s
//!   desired-state shape rather than a fragile command queue).
//!
//! The wire encoding lives in [`wire`]; the transports that carry the
//! frames live in [`crate::net`].
//!
//! ## Version history (the handshake contract)
//!
//! All evolution so far is same-major: new fields ride the *trailing
//! extension room* of existing payloads (decoders ignore bytes past the
//! fields they know), so older peers interoperate unchanged.
//!
//! * **v1.0** — the base protocol: every frame is `u32` big-endian
//!   length + payload, every payload is a tag byte + fields.
//! * **v1.1** — epoch trailers: each response carries the serving
//!   master's epoch (term) for split-brain fencing after a takeover.
//! * **v1.2** — slave self-registration and batched directive acks.
//! * **v1.3** — retry ids on `Submit`/`Complete` for exactly-once
//!   mutation across failover re-dials.
//!
//! See [`PROTO_MINOR`] for the per-version details.
//!
//! ## Example: one frame round trip
//!
//! ```
//! use dorm::proto::{wire, Request, PROTO_MAJOR, PROTO_MINOR};
//!
//! // every connection opens with Hello; encode it, frame it, decode it
//! let payload =
//!     wire::encode_request(&Request::Hello { major: PROTO_MAJOR, minor: PROTO_MINOR });
//! let mut framed = Vec::new();
//! wire::write_frame(&mut framed, &payload, 64 * 1024).unwrap();
//! // the frame is the 4-byte big-endian payload length, then the payload
//! assert_eq!(&framed[..4], &(payload.len() as u32).to_be_bytes());
//! let body = wire::read_frame(&mut &framed[..], 64 * 1024).unwrap();
//! let (req, rid) = wire::decode_request_rid(&body).unwrap();
//! assert_eq!(rid, None, "Hello is never stamped with a retry id");
//! assert!(matches!(req, Request::Hello { .. }));
//! ```

#![deny(missing_docs)]

pub mod wire;

use std::fmt;

use crate::app::{AppId, AppSpec, AppState};
use crate::resources::Res;
use crate::slave::SlaveReport;

/// Protocol major version: incompatible wire or semantics changes.
pub const PROTO_MAJOR: u16 = 1;
/// Protocol minor version: backward-compatible additions within a major.
/// v1.1 added the master epoch (term) number: every response frame is
/// trailed by the serving master's epoch ([`wire::encode_response_ep`])
/// and [`StateView`] carries it, which is what lets slaves and `dorm ctl`
/// fence off a deposed primary after a standby takeover (DESIGN.md §11).
/// v1.2 added slave self-registration ([`Request::Register`] /
/// [`Response::Registered`], so a slave can join without a preassigned
/// `--index` ordinate) and batched directive acknowledgements: a
/// [`Request::Heartbeat`] carries the [`DirectiveAck`]s for every
/// directive applied since the previous beat, replacing one ack
/// round-trip per directive.  Both ride the trailing extension room of
/// existing frames, so a v1.1 peer still decodes v1.2 traffic.
/// v1.3 added retry ids: a client may stamp `Submit`/`Complete` with a
/// generated id ([`wire::encode_request_rid`]); the master remembers the
/// last few (id → response) pairs, so a `FailoverTransport` re-send across
/// a takeover re-dial returns the cached response instead of double-
/// applying the mutation.  The id rides the trailing extension room, so
/// older peers interoperate unchanged.
/// (Error code 14, [`ErrorCode::TooManyConnections`], was added within
/// v1.3: an unrecognized code degrades to [`ErrorCode::Internal`] on
/// older peers, so new codes never need a version bump.)
pub const PROTO_MINOR: u16 = 3;

/// Version handshake rule: same major, minor no newer than ours (a newer
/// minor may legally send request tags we cannot decode, so it is refused
/// up front with a decodable error instead of failing mid-session).
pub fn negotiate(major: u16, minor: u16) -> Result<(), ProtoError> {
    if major != PROTO_MAJOR || minor > PROTO_MINOR {
        return Err(ProtoError {
            code: ErrorCode::VersionMismatch,
            detail: format!(
                "peer speaks v{major}.{minor}, this master speaks v{PROTO_MAJOR}.{PROTO_MINOR}"
            ),
        });
    }
    Ok(())
}

/// A control-plane request (client → master).
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Version handshake; must be the first frame on a TCP connection.
    Hello { major: u16, minor: u16 },
    /// §III-B submission (the 6-tuple).
    Submit { spec: AppSpec },
    /// App finished / cancelled; free its partition, re-optimize.
    Complete { app: AppId },
    /// Slave liveness + (optionally) its xᵢⱼ column.  `now_hours` is the
    /// sender's clock; over TCP a non-finite value means "stamp at
    /// arrival" and the server substitutes its own wall clock (a slave
    /// must not have to agree with the master about time).  `acks`
    /// reports, in one batch, the fate of every directive the slave
    /// applied since its previous beat (v1.2; empty from older peers).
    Heartbeat {
        server: u32,
        now_hours: f64,
        report: Option<SlaveReport>,
        acks: Vec<DirectiveAck>,
    },
    /// A slave joins by name, without a preassigned ordinate (v1.2).  The
    /// master matches `name` against its server book (or seats the slave
    /// at the first unregistered ordinate, adopting `capacity`) and
    /// answers [`Response::Registered`] with the ordinate to heartbeat
    /// as.  Re-registering a name whose seat is alive is refused with
    /// [`ErrorCode::AlreadyRegistered`].
    Register { name: String, capacity: Res },
    /// Admin/testing: place containers on a server's book directly.
    CreateContainers {
        server: u32,
        app: AppId,
        demand: Res,
        count: u32,
    },
    /// Admin/testing: remove containers (`count = None` destroys all).
    Destroy {
        server: u32,
        app: AppId,
        count: Option<u32>,
    },
    /// Persist a checkpoint for one running app (periodic checkpointing).
    CheckpointApp { app: AppId },
    /// Bookkeeping progress for masters without a compute service.
    AdvanceSteps { app: AppId, steps: u64 },
    /// Force a snapshot→solve→enforce round.
    Reallocate,
    /// Declare every server with a lapsed lease dead (same clock domain
    /// as [`Request::Heartbeat`]; non-finite = server wall clock).
    ExpireLeases { now_hours: f64 },
    /// Failure injection: the server is dead right now.
    FailServer { server: u32 },
    /// The server rejoined empty at original capacity.
    RecoverServer { server: u32, now_hours: f64 },
    /// Observable master state; `app` filters to one application.
    QueryState { app: Option<AppId> },
    /// Stop serving (TCP server drains and exits; local no-op).
    Shutdown,
}

/// A control-plane response (master → client).
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    /// Handshake accepted; carries the master's version.
    HelloAck { major: u16, minor: u16 },
    /// Request applied; nothing further to report.
    Ok,
    /// Submission accepted; the id to address the app by from now on.
    Submitted { app: AppId },
    /// Heartbeat consumed.  `alive` is the lease verdict (a dead server's
    /// late heartbeat does not resurrect it — it must send
    /// [`Request::RecoverServer`]); `directives` converge the reporting
    /// slave's book on the master's.
    HeartbeatAck {
        alive: bool,
        directives: Vec<Directive>,
    },
    /// Registration accepted: heartbeat as this server ordinate (v1.2).
    Registered { server: u32 },
    /// Servers newly declared dead by [`Request::ExpireLeases`].
    Expired { dead: Vec<u32> },
    /// Apps degraded by [`Request::FailServer`].
    Affected { apps: Vec<AppId> },
    /// Answer to [`Request::QueryState`].
    State(StateView),
    /// Typed refusal; the connection stays usable unless the code says
    /// otherwise ([`ErrorCode::FrameTooLarge`] is fatal to framing).
    Error(ProtoError),
}

/// Master→slave container command, piggybacked on the heartbeat ack.
#[derive(Clone, Debug, PartialEq)]
pub enum Directive {
    /// Launch `count` containers of `demand` each for `app`.
    Create { app: AppId, demand: Res, count: u32 },
    /// Tear down `count` of `app`'s containers.
    Destroy { app: AppId, count: u32 },
    /// Tear down every container `app` still holds on this slave.
    DestroyAll { app: AppId },
}

/// Which kind of [`Directive`] a [`DirectiveAck`] answers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AckKind {
    /// Answers a [`Directive::Create`].
    Create,
    /// Answers a [`Directive::Destroy`].
    Destroy,
    /// Answers a [`Directive::DestroyAll`].
    DestroyAll,
}

/// One directive's outcome, batched onto the *next* heartbeat (v1.2).
/// The protocol stays correct without acks — reconciliation re-derives
/// any lost directive on the following beat — so acks are telemetry the
/// master counts, not a delivery guarantee it depends on.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DirectiveAck {
    /// The app the answered directive was for.
    pub app: AppId,
    /// The kind of directive being answered.
    pub kind: AckKind,
    /// `false`: the slave tried and failed (e.g. local capacity check);
    /// the master's reconcile loop will re-issue or correct course.
    pub applied: bool,
}

/// Typed error category; the wire carries the code, `detail` is advisory.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorCode {
    /// Handshake refused (major mismatch or newer minor).
    VersionMismatch,
    /// A non-Hello frame arrived before the handshake completed.
    HandshakeRequired,
    /// Payload failed to decode (truncated fields, bad enum value, ...).
    MalformedFrame,
    /// Frame length exceeds the negotiated limit (fatal to the connection).
    FrameTooLarge,
    /// Unknown request tag (e.g. a newer peer's new message).
    UnsupportedRequest,
    /// No app with the given id (or it was forgotten after completion).
    UnknownApp,
    /// Server ordinate outside the cluster's seat range.
    UnknownServer,
    /// Submission rejected by `AppSpec::validate`.
    InvalidSpec,
    /// The app's lifecycle state forbids the operation.
    InvalidState,
    /// A field value is out of domain (non-finite time, zero count, ...).
    InvalidArgument,
    /// Anything else; `detail` has the underlying error chain.
    Internal,
    /// The answering master's epoch is older than one the caller has
    /// already seen: it is a deposed primary and its writes must be
    /// refused (split-brain fencing, DESIGN.md §11).
    StaleEpoch,
    /// [`Request::Register`] for a name whose seat is already registered
    /// and alive — almost always a duplicate slave process; the live
    /// holder keeps its seat.
    AlreadyRegistered,
    /// The server is at its `[net].max_conns` connection limit; this
    /// connection is answered and closed.  Back off and re-dial — an
    /// existing connection closing frees a seat.
    TooManyConnections,
}

impl ErrorCode {
    /// Encode for the wire; the inverse of [`ErrorCode::from_u16`].
    pub fn as_u16(self) -> u16 {
        match self {
            ErrorCode::VersionMismatch => 1,
            ErrorCode::HandshakeRequired => 2,
            ErrorCode::MalformedFrame => 3,
            ErrorCode::FrameTooLarge => 4,
            ErrorCode::UnsupportedRequest => 5,
            ErrorCode::UnknownApp => 6,
            ErrorCode::UnknownServer => 7,
            ErrorCode::InvalidSpec => 8,
            ErrorCode::InvalidState => 9,
            ErrorCode::InvalidArgument => 10,
            ErrorCode::Internal => 11,
            ErrorCode::StaleEpoch => 12,
            ErrorCode::AlreadyRegistered => 13,
            ErrorCode::TooManyConnections => 14,
        }
    }

    /// Decode; an unrecognized code (newer peer) degrades to `Internal`
    /// rather than failing the whole frame.
    pub fn from_u16(v: u16) -> ErrorCode {
        match v {
            1 => ErrorCode::VersionMismatch,
            2 => ErrorCode::HandshakeRequired,
            3 => ErrorCode::MalformedFrame,
            4 => ErrorCode::FrameTooLarge,
            5 => ErrorCode::UnsupportedRequest,
            6 => ErrorCode::UnknownApp,
            7 => ErrorCode::UnknownServer,
            8 => ErrorCode::InvalidSpec,
            9 => ErrorCode::InvalidState,
            10 => ErrorCode::InvalidArgument,
            12 => ErrorCode::StaleEpoch,
            13 => ErrorCode::AlreadyRegistered,
            14 => ErrorCode::TooManyConnections,
            _ => ErrorCode::Internal,
        }
    }
}

/// A typed control-plane error, decodable on the remote side.
#[derive(Clone, Debug, PartialEq)]
pub struct ProtoError {
    /// The machine-readable category a client can branch on.
    pub code: ErrorCode,
    /// Human-readable diagnosis; advisory, never parsed.
    pub detail: String,
}

impl ProtoError {
    /// Build an error from a code and anything displayable.
    pub fn new(code: ErrorCode, detail: impl fmt::Display) -> Self {
        ProtoError { code, detail: detail.to_string() }
    }
}

impl fmt::Display for ProtoError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:?}: {}", self.code, self.detail)
    }
}

impl std::error::Error for ProtoError {}

/// Observable master state — everything the parity tests compare and the
/// `dorm ctl query` command prints.  Scalar aggregates plus one row per
/// (non-filtered) app; no paths or clocks that differ across processes.
#[derive(Clone, Debug, PartialEq)]
pub struct StateView {
    /// Master event clock (one tick per mutating control-plane event).
    pub clock: u64,
    /// Serving master's epoch (term).  A standby takeover serves the same
    /// logical state at `epoch + 1`; views from different epochs must not
    /// be treated as one history.
    pub epoch: u64,
    /// Servers whose liveness lease has not lapsed.
    pub alive_servers: u32,
    /// Cluster seats, alive or not.
    pub total_servers: u32,
    /// Apps in a non-terminal state.
    pub active_apps: u32,
    /// Lifetime count of resource adjustments (Fig. 9b's numerator).
    pub total_adjustments: u32,
    /// Lifetime count of checkpoint-driven app recoveries.
    pub total_recoveries: u32,
    /// Eq. 1 over alive servers.
    pub utilization: f64,
    /// One row per non-filtered app.
    pub apps: Vec<AppView>,
}

/// One application row of a [`StateView`].
#[derive(Clone, Debug, PartialEq)]
pub struct AppView {
    /// The app's id, as assigned by [`Response::Submitted`].
    pub id: AppId,
    /// Lifecycle state.
    pub state: AppState,
    /// Containers currently placed across the cluster.
    pub containers: u32,
    /// Training steps completed.
    pub steps_done: u64,
    /// Step of the latest durable checkpoint.
    pub ckpt_step: u64,
    /// Resource adjustments this app has absorbed.
    pub adjustments: u32,
    /// Checkpoint-driven recoveries this app has absorbed.
    pub recoveries: u32,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn negotiate_rules() {
        assert!(negotiate(PROTO_MAJOR, PROTO_MINOR).is_ok());
        assert!(negotiate(PROTO_MAJOR, 0).is_ok(), "older minor accepted");
        let newer_minor = negotiate(PROTO_MAJOR, PROTO_MINOR + 1).unwrap_err();
        assert_eq!(newer_minor.code, ErrorCode::VersionMismatch);
        let newer_major = negotiate(PROTO_MAJOR + 1, 0).unwrap_err();
        assert_eq!(newer_major.code, ErrorCode::VersionMismatch);
        let older_major = negotiate(0, 0).unwrap_err();
        assert_eq!(older_major.code, ErrorCode::VersionMismatch);
    }

    #[test]
    fn error_codes_roundtrip() {
        for code in [
            ErrorCode::VersionMismatch,
            ErrorCode::HandshakeRequired,
            ErrorCode::MalformedFrame,
            ErrorCode::FrameTooLarge,
            ErrorCode::UnsupportedRequest,
            ErrorCode::UnknownApp,
            ErrorCode::UnknownServer,
            ErrorCode::InvalidSpec,
            ErrorCode::InvalidState,
            ErrorCode::InvalidArgument,
            ErrorCode::Internal,
            ErrorCode::StaleEpoch,
            ErrorCode::AlreadyRegistered,
            ErrorCode::TooManyConnections,
        ] {
            assert_eq!(ErrorCode::from_u16(code.as_u16()), code);
        }
        // forward compatibility: a future code degrades, not fails
        assert_eq!(ErrorCode::from_u16(999), ErrorCode::Internal);
    }
}

"""L2 model contract tests: shapes, flat-param convention, learning."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import TfmConfig, make_lr, make_mf, make_tfm

RNG = np.random.default_rng(7)


def batch_for(spec, rng=RNG):
    if spec.x_dtype == "i32":
        hi = spec.meta.get("vocab") or spec.meta.get("n_users") or 2
        if spec.name == "mf":
            x = np.stack([rng.integers(0, spec.meta["n_users"], spec.x_shape[0]),
                          rng.integers(0, spec.meta["n_items"], spec.x_shape[0])],
                         axis=1).astype(np.int32)
        else:
            x = rng.integers(0, hi, spec.x_shape).astype(np.int32)
    else:
        x = rng.standard_normal(spec.x_shape).astype(np.float32)
    if spec.y_dtype == "i32":
        hi = spec.meta.get("vocab", 2)
        y = rng.integers(0, hi, spec.y_shape).astype(np.int32)
    else:
        y = (rng.standard_normal(spec.y_shape) > 0).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(y)


SPECS = [
    make_lr(d=16, batch=32),
    make_mf(n_users=64, n_items=32, k=8, batch=32),
    make_tfm(TfmConfig(vocab=128, d_model=32, n_layers=1, n_heads=2,
                       seq=16, batch=2), "tfm_test"),
]


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.name)
def test_contract_shapes(spec):
    p = spec.init(0)
    assert p.shape == (spec.n_params,) and p.dtype == jnp.float32
    x, y = batch_for(spec)
    loss, g = spec.grad(p, x, y)
    assert loss.shape == () and g.shape == (spec.n_params,)
    assert np.isfinite(float(loss)) and np.isfinite(np.asarray(g)).all()
    p2 = spec.apply(p, g, jnp.float32(1.0), jnp.float32(0.1))
    assert p2.shape == (spec.n_params,)


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.name)
def test_init_deterministic_and_seed_sensitive(spec):
    a, b, c = spec.init(3), spec.init(3), spec.init(4)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(np.asarray(a), np.asarray(c))


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: s.name)
def test_training_reduces_loss(spec):
    p = spec.init(0)
    x, y = batch_for(spec)
    grad = jax.jit(spec.grad)
    apply = jax.jit(spec.apply)
    loss0, _ = grad(p, x, y)
    lr = jnp.float32(0.5 if spec.name != "tfm_test" else 0.1)
    for _ in range(20):
        _, g = grad(p, x, y)
        p = apply(p, g, jnp.float32(1.0), lr)
    loss1, _ = grad(p, x, y)
    assert float(loss1) < float(loss0), (float(loss0), float(loss1))


def test_apply_is_sgd_over_mean():
    spec = SPECS[0]
    p = spec.init(0)
    g = jnp.ones_like(p)
    out = spec.apply(p, 4.0 * g, jnp.float32(4.0), jnp.float32(0.25))
    np.testing.assert_allclose(out, p - 0.25, rtol=1e-6)


def test_data_parallel_equivalence():
    """grad over a full batch == weighted combination of shard grads —
    the invariant Dorm's elastic rescaling relies on (same math at any
    worker count)."""
    spec = make_lr(d=8, batch=32)
    p = spec.init(1)
    x, y = batch_for(spec)
    _, g_full = spec.grad(p, x, y)
    halves = [spec.grad(p, x[:16], y[:16])[1], spec.grad(p, x[16:], y[16:])[1]]
    g_sharded = (halves[0] + halves[1]) / 2.0
    np.testing.assert_allclose(g_full, g_sharded, rtol=1e-5, atol=1e-6)


def test_lr_grad_matches_manual():
    """LR gradient against the closed form: X^T (sigmoid(Xw+b) - y) / B."""
    spec = make_lr(d=4, batch=8)
    p = spec.init(2)
    x, y = batch_for(spec)
    _, g = spec.grad(p, x, y)
    # ravel_pytree orders dict keys alphabetically: params = [b, w...].
    b, w = np.asarray(p[:1]), np.asarray(p[1:]).reshape(4, 1)
    z = np.asarray(x) @ w + b
    s = 1 / (1 + np.exp(-z))
    resid = (s[:, 0] - np.asarray(y)) / 8.0
    gw = np.asarray(x).T @ resid
    gb = resid.sum()
    np.testing.assert_allclose(g[1:], gw, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(g[0], gb, rtol=1e-4, atol=1e-5)

//! Master high availability: durable self-checkpoints, WAL replay, and
//! epoch-fenced takeover (DESIGN.md §11).
//!
//! The paper's §III-C protocol checkpoints *applications* through reliable
//! storage; this module applies the same discipline to the CMS master
//! itself, closing the single-point-of-control gap.  Three pieces:
//!
//! * [`MasterCheckpoint`] — the full serialized master: apps and their
//!   [`super::ManagedApp`] phases, the event clock and counters, the slave
//!   books (per-slave container groups, so even admin-created containers
//!   with non-spec demands survive), the lease table, the
//!   [`RecoveryLog`], the Dorm θ thresholds (to rebuild the policy), and a
//!   books digest that cross-checks the rebuilt placement state.  The
//!   byte format reuses the wire primitives ([`wire::Cur`]) and the
//!   digest-guarded, atomic-rename discipline of the app checkpoints.
//! * **WAL** — between full snapshots, every mutating [`Request`] is
//!   appended (in its existing wire encoding) to `master.wal`, each
//!   record digest-guarded and stamped with `(epoch, seq)`.  Replay is
//!   deterministic because `DormMaster::dispatch` is; the only handlers
//!   that *read* the checkpoint store (`FailServer`, `ExpireLeases`) are
//!   barriers that force a fresh snapshot instead, so replay never races
//!   the store's file state.
//! * [`load_master`] — newest digest-valid snapshot (corrupt ones are
//!   skipped, falling back to the previous good file, mirroring the app
//!   checkpoint fallback) plus the WAL tail at the *same epoch*.  Records
//!   from an older epoch are refused: a deposed primary that kept
//!   appending after a standby promoted (and re-snapshotted at
//!   `epoch + 1`) cannot leak its writes back into history.
//!
//! What is **not** replicated: trainers and the compute service (a
//! restored master starts with bookkeeping apps — recovery re-attaches
//! compute exactly like the artifacts-less masters the control-plane
//! tests drive), engine caches (dropped and rebuilt on first solve), and
//! in-flight requests (clients re-send; `FailoverTransport` re-dials).

use std::collections::{BTreeMap, VecDeque};
use std::io::Write;

use anyhow::{bail, Context, Result};

use crate::app::checkpoint::fnv1a;
use crate::app::{AppId, AppSpec, AppState, CheckpointStore};
use crate::config::DormConfig;
use crate::fault::{LeaseTable, RecoveryLog, RecoveryRecord};
use crate::optimizer::SolveMode;
use crate::proto::wire::{self, Cur};
use crate::proto::Request;
use crate::resources::Res;
use crate::sched::{CellScheduler, CellsSnapshot, CmsPolicy, DormPolicy};
use crate::slave::DormSlave;

use super::{DormMaster, ManagedApp};

const MAGIC: &[u8; 8] = b"DORMMSTR";
/// v2 appended the registration bits and the sharded scheduler's cell map
/// (routing pins + partition parameters); v1 files still load, with no
/// registrations and an unsharded policy.
const VERSION: u32 = 2;

/// How [`DormMaster::dispatch`] journals one request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HaAction {
    /// Read-only or connection-scoped: nothing to persist.
    Skip,
    /// Mutating and store-oblivious: append to the WAL (amortized).
    Append,
    /// Mutating and store-*reading* (`fail_servers` probes app
    /// checkpoints): force a full snapshot so a later replay never sees a
    /// different store than the original handling did.
    Barrier,
}

impl HaAction {
    pub fn of(req: &Request) -> HaAction {
        match req {
            Request::Hello { .. } | Request::QueryState { .. } | Request::Shutdown => {
                HaAction::Skip
            }
            Request::FailServer { .. } | Request::ExpireLeases { .. } => HaAction::Barrier,
            _ => HaAction::Append,
        }
    }
}

// ---- the full snapshot --------------------------------------------------

/// One slave's serialized book.
#[derive(Clone, Debug, PartialEq)]
pub struct SlaveSnap {
    pub name: String,
    pub capacity: Res,
    pub alive: bool,
    /// Lease renewal timestamp (the snapshotting master's clock domain).
    pub renewed: f64,
    /// Containers grouped by `(app, demand)`, insertion-ordered.
    pub groups: Vec<(AppId, Res, u32)>,
}

/// One managed app's serialized phase.
#[derive(Clone, Debug, PartialEq)]
pub struct AppSnap {
    pub id: AppId,
    pub spec: AppSpec,
    pub state: AppState,
    pub adjustments: u32,
    pub recoveries: u32,
    pub steps_done: u64,
    pub ckpt_step: u64,
    pub ckpt_restorable: bool,
}

/// The versioned, digest-guarded serialization of a whole [`DormMaster`].
#[derive(Clone, Debug, PartialEq)]
pub struct MasterCheckpoint {
    pub epoch: u64,
    /// WAL sequence number this snapshot covers: replay applies only
    /// records with the same epoch and a larger seq.
    pub seq: u64,
    pub clock: u64,
    pub next_id: u64,
    pub total_adjustments: u32,
    pub total_recoveries: u32,
    pub theta1: f64,
    pub theta2: f64,
    pub ckpt_retain: u32,
    pub lease_timeout: f64,
    pub slaves: Vec<SlaveSnap>,
    pub apps: Vec<AppSnap>,
    pub log: Vec<RecoveryRecord>,
    /// Which seats were claimed through the Register RPC (v2; `--index`
    /// slaves never set their bit).  Same length as `slaves`.
    pub registered: Vec<bool>,
    /// The sharded scheduler's cell map, when the snapshotting master ran
    /// one (v2).  `None` restores a plain single-engine policy.
    pub cells: Option<CellsSnapshot>,
    /// FNV over the canonical slave-book encoding; [`restore`] recomputes
    /// it from the rebuilt books and refuses a mismatch (a serialization
    /// or rebuild bug must fail loudly, not mis-place containers).
    pub books_digest: u64,
}

/// Canonical encoding of the slave books for [`MasterCheckpoint::books_digest`].
fn encode_books(slaves: &[SlaveSnap]) -> Vec<u8> {
    let mut out = Vec::new();
    for s in slaves {
        wire::put_str(&mut out, &s.name);
        for (app, demand, count) in &s.groups {
            out.extend_from_slice(&app.0.to_be_bytes());
            wire::put_res(&mut out, demand);
            out.extend_from_slice(&count.to_be_bytes());
        }
    }
    out
}

impl MasterCheckpoint {
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(256);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&VERSION.to_be_bytes());
        out.extend_from_slice(&self.epoch.to_be_bytes());
        out.extend_from_slice(&self.seq.to_be_bytes());
        out.extend_from_slice(&self.clock.to_be_bytes());
        out.extend_from_slice(&self.next_id.to_be_bytes());
        out.extend_from_slice(&self.total_adjustments.to_be_bytes());
        out.extend_from_slice(&self.total_recoveries.to_be_bytes());
        wire::put_f64(&mut out, self.theta1);
        wire::put_f64(&mut out, self.theta2);
        out.extend_from_slice(&self.ckpt_retain.to_be_bytes());
        wire::put_f64(&mut out, self.lease_timeout);
        out.extend_from_slice(&(self.slaves.len() as u32).to_be_bytes());
        for s in &self.slaves {
            wire::put_str(&mut out, &s.name);
            wire::put_res(&mut out, &s.capacity);
            out.push(u8::from(s.alive));
            wire::put_f64(&mut out, s.renewed);
            out.extend_from_slice(&(s.groups.len() as u32).to_be_bytes());
            for (app, demand, count) in &s.groups {
                out.extend_from_slice(&app.0.to_be_bytes());
                wire::put_res(&mut out, demand);
                out.extend_from_slice(&count.to_be_bytes());
            }
        }
        out.extend_from_slice(&(self.apps.len() as u32).to_be_bytes());
        for a in &self.apps {
            out.extend_from_slice(&a.id.0.to_be_bytes());
            wire::put_spec(&mut out, &a.spec);
            out.push(wire::state_tag(a.state));
            out.extend_from_slice(&a.adjustments.to_be_bytes());
            out.extend_from_slice(&a.recoveries.to_be_bytes());
            out.extend_from_slice(&a.steps_done.to_be_bytes());
            out.extend_from_slice(&a.ckpt_step.to_be_bytes());
            out.push(u8::from(a.ckpt_restorable));
        }
        out.extend_from_slice(&(self.log.len() as u32).to_be_bytes());
        for r in &self.log {
            out.extend_from_slice(&r.app.0.to_be_bytes());
            out.extend_from_slice(&(r.server as u64).to_be_bytes());
            wire::put_f64(&mut out, r.failed_at);
            wire::put_f64(&mut out, r.lost_work);
            match r.resumed_at {
                None => out.push(0),
                Some(t) => {
                    out.push(1);
                    wire::put_f64(&mut out, t);
                }
            }
            out.extend_from_slice(&r.resumed_scale.to_be_bytes());
        }
        // v2: registration bits + optional cell map, ahead of the digest
        out.extend_from_slice(&(self.registered.len() as u32).to_be_bytes());
        for &r in &self.registered {
            out.push(u8::from(r));
        }
        match &self.cells {
            None => out.push(0),
            Some(cs) => {
                out.push(1);
                out.extend_from_slice(&cs.count.to_be_bytes());
                out.extend_from_slice(&cs.rebalance_every.to_be_bytes());
                wire::put_f64(&mut out, cs.imbalance_threshold);
                out.extend_from_slice(&(cs.routes.len() as u32).to_be_bytes());
                for (app, cell) in &cs.routes {
                    out.extend_from_slice(&app.0.to_be_bytes());
                    out.extend_from_slice(&cell.to_be_bytes());
                }
            }
        }
        out.extend_from_slice(&self.books_digest.to_be_bytes());
        let digest = fnv1a(&out);
        out.extend_from_slice(&digest.to_le_bytes());
        out
    }

    /// Parse + verify the trailing digest (same guard as the app format).
    pub fn from_bytes(bytes: &[u8]) -> Result<MasterCheckpoint> {
        if bytes.len() < MAGIC.len() + 4 + 8 {
            bail!("master checkpoint truncated ({} bytes)", bytes.len());
        }
        let (body, digest_bytes) = bytes.split_at(bytes.len() - 8);
        let expect = u64::from_le_bytes(digest_bytes.try_into().unwrap());
        if fnv1a(body) != expect {
            bail!("master checkpoint digest mismatch (corrupt file)");
        }
        let mut c = Cur::new(body);
        if c.take(8)? != MAGIC {
            bail!("bad master checkpoint magic");
        }
        let version = c.u32()?;
        if version == 0 || version > VERSION {
            bail!("unsupported master checkpoint version {version}");
        }
        let epoch = c.u64()?;
        let seq = c.u64()?;
        let clock = c.u64()?;
        let next_id = c.u64()?;
        let total_adjustments = c.u32()?;
        let total_recoveries = c.u32()?;
        let theta1 = c.f64()?;
        let theta2 = c.f64()?;
        let ckpt_retain = c.u32()?;
        let lease_timeout = c.f64()?;
        let n_slaves = c.count(1)?;
        let mut slaves = Vec::with_capacity(n_slaves);
        for _ in 0..n_slaves {
            let name = c.str()?;
            let capacity = c.res()?;
            let alive = c.bool()?;
            let renewed = c.f64()?;
            let n_groups = c.count(16)?;
            let mut groups = Vec::with_capacity(n_groups);
            for _ in 0..n_groups {
                groups.push((AppId(c.u64()?), c.res()?, c.u32()?));
            }
            slaves.push(SlaveSnap { name, capacity, alive, renewed, groups });
        }
        let n_apps = c.count(1)?;
        let mut apps = Vec::with_capacity(n_apps);
        for _ in 0..n_apps {
            apps.push(AppSnap {
                id: AppId(c.u64()?),
                spec: wire::spec(&mut c)?,
                state: wire::state_of(c.u8()?)?,
                adjustments: c.u32()?,
                recoveries: c.u32()?,
                steps_done: c.u64()?,
                ckpt_step: c.u64()?,
                ckpt_restorable: c.bool()?,
            });
        }
        let n_log = c.count(1)?;
        let mut log = Vec::with_capacity(n_log);
        for _ in 0..n_log {
            log.push(RecoveryRecord {
                app: AppId(c.u64()?),
                server: c.u64()? as usize,
                failed_at: c.f64()?,
                lost_work: c.f64()?,
                resumed_at: if c.bool()? { Some(c.f64()?) } else { None },
                resumed_scale: c.u32()?,
            });
        }
        let (registered, cells) = if version >= 2 {
            let n_reg = c.count(1)?;
            let mut registered = Vec::with_capacity(n_reg);
            for _ in 0..n_reg {
                registered.push(c.bool()?);
            }
            let cells = if c.bool()? {
                let count = c.u32()?;
                let rebalance_every = c.u64()?;
                let imbalance_threshold = c.f64()?;
                let n_routes = c.count(12)?;
                let mut routes = Vec::with_capacity(n_routes);
                for _ in 0..n_routes {
                    routes.push((AppId(c.u64()?), c.u32()?));
                }
                Some(CellsSnapshot { count, rebalance_every, imbalance_threshold, routes })
            } else {
                None
            };
            (registered, cells)
        } else {
            // v1 predates both the Register RPC and the sharded scheduler
            (vec![false; n_slaves], None)
        };
        let books_digest = c.u64()?;
        Ok(MasterCheckpoint {
            epoch,
            seq,
            clock,
            next_id,
            total_adjustments,
            total_recoveries,
            theta1,
            theta2,
            ckpt_retain,
            lease_timeout,
            slaves,
            apps,
            log,
            registered,
            cells,
            books_digest,
        })
    }
}

/// Serialize the master's full state.  `seq` is stamped by
/// [`HaLog::write_snapshot`].
pub fn snapshot_state(m: &DormMaster) -> MasterCheckpoint {
    let (lease_timeout, renewed, alive) = m.lease.to_parts();
    let slaves: Vec<SlaveSnap> = m
        .slaves
        .iter()
        .enumerate()
        .map(|(j, s)| SlaveSnap {
            name: s.name.clone(),
            capacity: s.capacity().clone(),
            alive: alive[j],
            renewed: renewed[j],
            groups: s.container_groups(),
        })
        .collect();
    let books_digest = fnv1a(&encode_books(&slaves));
    MasterCheckpoint {
        epoch: m.epoch,
        seq: 0,
        clock: m.clock,
        next_id: m.next_id,
        total_adjustments: m.total_adjustments,
        total_recoveries: m.total_recoveries,
        theta1: m.dorm_cfg.theta1,
        theta2: m.dorm_cfg.theta2,
        ckpt_retain: m.ckpt_retain as u32,
        lease_timeout,
        slaves,
        apps: m
            .apps
            .values()
            .map(|a| AppSnap {
                id: a.id,
                spec: a.spec.clone(),
                state: a.state,
                adjustments: a.adjustments,
                recoveries: a.recoveries,
                steps_done: a.steps_done,
                ckpt_step: a.ckpt_step,
                ckpt_restorable: a.ckpt_restorable,
            })
            .collect(),
        log: m.recovery_log.records().to_vec(),
        registered: m.registered.clone(),
        cells: m.policy.cells_snapshot(),
        books_digest,
    }
}

/// Rebuild an equivalent master from a snapshot: the Dorm policy is
/// reconstructed from the stored θ thresholds with empty caches (the
/// engine re-derives them on the first solve), trainers/compute are not
/// re-attached (module docs), and the rebuilt slave books are verified
/// against the snapshot's digest.
pub fn restore(ckpt: &MasterCheckpoint, store: CheckpointStore) -> Result<DormMaster> {
    let cfg = DormConfig { theta1: ckpt.theta1, theta2: ckpt.theta2 };
    let policy: Box<dyn CmsPolicy> = match &ckpt.cells {
        // the snapshotting master ran sharded: rebuild the same partition
        // and routing pins so takeover keeps every app in its cell
        Some(cs) => Box::new(CellScheduler::from_snapshot(cfg, cs, ckpt.slaves.len())),
        None => Box::new(DormPolicy::with_mode(cfg, SolveMode::Heuristic)),
    };
    restore_with_policy(ckpt, policy, store)
}

/// [`restore`] with an explicit policy (tests, baseline-driven masters).
pub fn restore_with_policy(
    ckpt: &MasterCheckpoint,
    mut policy: Box<dyn CmsPolicy>,
    store: CheckpointStore,
) -> Result<DormMaster> {
    let mut slaves = Vec::with_capacity(ckpt.slaves.len());
    let mut renewed = Vec::with_capacity(ckpt.slaves.len());
    let mut alive = Vec::with_capacity(ckpt.slaves.len());
    for snap in &ckpt.slaves {
        let mut s = DormSlave::new(snap.name.clone(), snap.capacity.clone());
        for (app, demand, count) in &snap.groups {
            s.create(*app, demand, *count)
                .with_context(|| format!("rebuilding book of {}", snap.name))?;
        }
        renewed.push(snap.renewed);
        alive.push(snap.alive);
        slaves.push(s);
    }
    // cross-check: the rebuilt books must hash to what was snapshotted
    let rebuilt: Vec<SlaveSnap> = slaves
        .iter()
        .enumerate()
        .map(|(j, s)| SlaveSnap {
            name: s.name.clone(),
            capacity: s.capacity().clone(),
            alive: alive[j],
            renewed: renewed[j],
            groups: s.container_groups(),
        })
        .collect();
    if fnv1a(&encode_books(&rebuilt)) != ckpt.books_digest {
        bail!("restored slave books do not match the snapshot's placement digest");
    }
    let mut apps = BTreeMap::new();
    for a in &ckpt.apps {
        apps.insert(
            a.id,
            ManagedApp {
                id: a.id,
                spec: a.spec.clone(),
                state: a.state,
                model: None,
                trainer: None,
                adjustments: a.adjustments,
                recoveries: a.recoveries,
                steps_done: a.steps_done,
                ckpt_step: a.ckpt_step,
                ckpt_restorable: a.ckpt_restorable,
            },
        );
    }
    // the policy's capacity-derived caches (if it carried any) predate
    // this cluster state; both backends drop them on restore
    policy.on_capacity_change();
    let mut registered = ckpt.registered.clone();
    registered.resize(ckpt.slaves.len(), false);
    Ok(DormMaster {
        slaves,
        policy,
        store,
        compute: None,
        apps,
        next_id: ckpt.next_id,
        clock: ckpt.clock,
        total_adjustments: ckpt.total_adjustments,
        total_recoveries: ckpt.total_recoveries,
        lease: LeaseTable::from_parts(ckpt.lease_timeout, renewed, alive),
        registered,
        directive_acks: 0,
        directive_nacks: 0,
        recovery_log: RecoveryLog::from_records(ckpt.log.clone()),
        ckpt_retain: ckpt.ckpt_retain as usize,
        epoch: ckpt.epoch,
        dorm_cfg: DormConfig { theta1: ckpt.theta1, theta2: ckpt.theta2 },
        ha: None,
        // retry-dedupe memory is not snapshotted; [`load_master`]'s WAL
        // replay repopulates it for every journaled rid-stamped request
        dedupe: VecDeque::new(),
    })
}

// ---- the write-ahead log ------------------------------------------------

/// One WAL entry: a mutating request at `(epoch, seq)`.
#[derive(Clone, Debug, PartialEq)]
pub struct WalRecord {
    pub epoch: u64,
    pub seq: u64,
    pub bytes: Vec<u8>,
}

const WAL_HEADER: usize = 8 + 8 + 4; // epoch, seq, len

fn encode_wal_record(epoch: u64, seq: u64, bytes: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(WAL_HEADER + bytes.len() + 8);
    out.extend_from_slice(&epoch.to_be_bytes());
    out.extend_from_slice(&seq.to_be_bytes());
    out.extend_from_slice(&(bytes.len() as u32).to_be_bytes());
    out.extend_from_slice(bytes);
    let digest = fnv1a(&out);
    out.extend_from_slice(&digest.to_le_bytes());
    out
}

/// Read every intact record; a torn or corrupt tail (e.g. a `kill -9`
/// mid-append) truncates the replay there instead of failing the load —
/// exactly the "in-flight requests are lost" contract of takeover.
pub fn read_wal(store: &CheckpointStore) -> Result<Vec<WalRecord>> {
    let path = store.wal_path();
    let buf = match std::fs::read(&path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
        Err(e) => return Err(e).with_context(|| format!("reading {}", path.display())),
    };
    let mut out = Vec::new();
    let mut pos = 0usize;
    while buf.len() - pos >= WAL_HEADER + 8 {
        let epoch = u64::from_be_bytes(buf[pos..pos + 8].try_into().unwrap());
        let seq = u64::from_be_bytes(buf[pos + 8..pos + 16].try_into().unwrap());
        let len = u32::from_be_bytes(buf[pos + 16..pos + 20].try_into().unwrap()) as usize;
        let end = pos + WAL_HEADER + len;
        if end + 8 > buf.len() {
            log::warn!("WAL record at offset {pos} torn; stopping replay");
            break;
        }
        let expect = u64::from_le_bytes(buf[end..end + 8].try_into().unwrap());
        if fnv1a(&buf[pos..end]) != expect {
            log::warn!("WAL record at offset {pos} corrupt; stopping replay");
            break;
        }
        out.push(WalRecord {
            epoch,
            seq,
            bytes: buf[pos + WAL_HEADER..end].to_vec(),
        });
        pos = end + 8;
    }
    Ok(out)
}

/// The master's self-checkpointing state (armed via `DormMaster::with_ha`).
pub(crate) struct HaLog {
    store: CheckpointStore,
    snapshot_every: u64,
    retain: usize,
    seq: u64,
    /// WAL records appended since the last full snapshot.
    pending: u64,
}

impl HaLog {
    pub(crate) fn new(
        store: CheckpointStore,
        snapshot_every: u64,
        retain: usize,
        start_seq: u64,
    ) -> Self {
        HaLog {
            store,
            snapshot_every: snapshot_every.max(1),
            retain: retain.max(1),
            seq: start_seq,
            pending: 0,
        }
    }

    pub(crate) fn snapshot_every(&self) -> u64 {
        self.snapshot_every
    }

    pub(crate) fn pending_records(&self) -> u64 {
        self.pending
    }

    /// Advance the sequence for an event persisted via snapshot (barrier
    /// or cadence rollover) rather than a WAL append.
    pub(crate) fn bump_seq(&mut self) {
        self.seq += 1;
    }

    /// Undo a [`HaLog::bump_seq`] whose persistence failed.  Leaving the
    /// bump in place would open a permanent sequence gap: every later
    /// append would be non-contiguous with the last good snapshot, so
    /// recovery would refuse the *entire* tail instead of losing just the
    /// one event whose write failed.
    pub(crate) fn rollback_seq(&mut self) {
        self.seq -= 1;
    }

    /// Append one mutating request to the WAL.  On failure the sequence
    /// is rolled back (see [`HaLog::rollback_seq`]) so the journal stays
    /// contiguous; the failed event alone is lost to recovery.
    pub(crate) fn append(&mut self, epoch: u64, encoded_req: &[u8]) -> Result<()> {
        self.seq += 1;
        let rec = encode_wal_record(epoch, self.seq, encoded_req);
        let result = (|| -> Result<()> {
            let path = self.store.wal_path();
            let mut f = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(&path)
                .with_context(|| format!("opening {}", path.display()))?;
            f.write_all(&rec)?;
            Ok(())
        })();
        match result {
            Ok(()) => {
                self.pending += 1;
                Ok(())
            }
            Err(e) => {
                self.seq -= 1;
                Err(e)
            }
        }
    }

    /// Persist a full snapshot at the current sequence, reset the WAL
    /// (its records are now covered), and apply retention.
    pub(crate) fn write_snapshot(&mut self, mut snap: MasterCheckpoint) -> Result<()> {
        snap.seq = self.seq;
        let bytes = snap.to_bytes();
        self.store
            .save_master(&bytes, snap.epoch, snap.seq)
            .context("saving master snapshot")?;
        std::fs::File::create(self.store.wal_path()).context("resetting master WAL")?;
        self.pending = 0;
        self.store.prune_master(self.retain)?;
        Ok(())
    }
}

/// Load the newest restorable master: newest digest-valid snapshot
/// (corrupt/truncated files fall back to the previous good one) plus the
/// same-epoch WAL tail replayed through `dispatch`.  Returns the restored
/// master and the last applied sequence number (feed it back to
/// `DormMaster::with_ha` so the journal continues), or `None` when the
/// store holds no master snapshot at all.
pub fn load_master(store: &CheckpointStore) -> Result<Option<(DormMaster, u64)>> {
    let files = store.master_files()?;
    let mut ckpt = None;
    for p in files.iter().rev() {
        match std::fs::read(p) {
            Ok(bytes) => match MasterCheckpoint::from_bytes(&bytes) {
                Ok(c) => {
                    ckpt = Some(c);
                    break;
                }
                Err(e) => log::warn!("skipping corrupt master snapshot {}: {e:#}", p.display()),
            },
            Err(e) => log::warn!("unreadable master snapshot {}: {e}", p.display()),
        }
    }
    let Some(ckpt) = ckpt else { return Ok(None) };
    let mut m = restore(&ckpt, store.clone())?;
    let mut seq = ckpt.seq;
    for rec in read_wal(store)? {
        if rec.epoch != ckpt.epoch {
            log::warn!(
                "refusing WAL record seq {} from epoch {} (snapshot epoch {}): \
                 deposed-primary write fenced off",
                rec.seq,
                rec.epoch,
                ckpt.epoch
            );
            continue;
        }
        if rec.seq <= ckpt.seq {
            continue; // already covered by the snapshot
        }
        if rec.seq != seq + 1 {
            // the WAL continues from a *newer* snapshot than the one we
            // could restore (fallback past a corrupt file): applying a
            // non-contiguous suffix would fabricate a state that never
            // existed — stop at the snapshot instead
            log::warn!(
                "WAL record seq {} is not contiguous with restored seq {seq}; \
                 stopping replay at the snapshot",
                rec.seq
            );
            break;
        }
        match wire::decode_request_rid(&rec.bytes) {
            Ok((req, rid)) => {
                // replay is best-effort per record: a typed error response
                // reproduces the original handling of that request.  The
                // rid (if journaled) re-enters the dedupe memory, so a
                // client retrying across the takeover still hits the cache
                let _ = m.dispatch_rid(req, rid);
                seq = rec.seq;
            }
            Err(e) => {
                log::warn!("stopping WAL replay at undecodable record {}: {e}", rec.seq);
                break;
            }
        }
    }
    Ok(Some((m, seq)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::app::Engine;
    use crate::config::ClusterConfig;

    fn store(tag: &str) -> CheckpointStore {
        let d = std::env::temp_dir().join(format!("dorm_ha_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        CheckpointStore::new(d).unwrap()
    }

    fn spec(n_max: u32) -> AppSpec {
        AppSpec {
            executor: Engine::MxNet,
            demand: Res::cpu_gpu_ram(2.0, 0.0, 8.0),
            weight: 1,
            n_max,
            n_min: 1,
            cmd: ["lr".into(), "lr".into()],
        }
    }

    fn sample_master(tag: &str) -> DormMaster {
        let mut m = DormMaster::new(
            &ClusterConfig::uniform(3, Res::cpu_gpu_ram(12.0, 0.0, 64.0)),
            DormConfig { theta1: 0.5, theta2: 0.5 },
            store(tag),
        );
        m.submit(spec(12)).unwrap();
        m.submit(spec(6)).unwrap();
        m.advance_steps(AppId(1), 40).unwrap();
        m
    }

    #[test]
    fn snapshot_bytes_roundtrip() {
        let m = sample_master("roundtrip");
        let snap = snapshot_state(&m);
        let back = MasterCheckpoint::from_bytes(&snap.to_bytes()).unwrap();
        assert_eq!(back, snap);
        assert_eq!(back.epoch, 1);
        assert_eq!(back.apps.len(), 2);
        assert!(back.slaves.iter().any(|s| !s.groups.is_empty()));
    }

    #[test]
    fn cell_map_and_registrations_survive_failover() {
        let cells = crate::config::CellsConfig {
            count: 2,
            rebalance_every: 8,
            imbalance_threshold: 1.5,
        };
        let mut m = DormMaster::with_cells(
            &ClusterConfig::uniform(4, Res::cpu_gpu_ram(12.0, 0.0, 64.0)),
            DormConfig { theta1: 0.5, theta2: 0.5 },
            &cells,
            store("cellmap"),
        );
        m.submit(spec(4)).unwrap();
        m.submit(spec(4)).unwrap();
        m.submit(spec(4)).unwrap();
        match m.dispatch(Request::Register {
            name: "joiner".into(),
            capacity: Res::cpu_gpu_ram(12.0, 0.0, 64.0),
        }) {
            crate::proto::Response::Registered { .. } => {}
            other => panic!("register failed: {other:?}"),
        }
        let snap = snapshot_state(&m);
        let cs = snap.cells.as_ref().expect("sharded master snapshots its cell map");
        assert_eq!(cs.count, 2);
        assert_eq!(cs.routes.len(), 3, "every live app keeps its routing pin");
        assert_eq!(snap.registered.iter().filter(|&&r| r).count(), 1);
        let back = MasterCheckpoint::from_bytes(&snap.to_bytes()).unwrap();
        assert_eq!(back, snap);
        let mut r = restore(&snap, m.store().clone()).unwrap();
        assert_eq!(r.state_view(None), m.state_view(None));
        assert_eq!(r.policy.cells_snapshot().as_ref(), Some(cs), "routing pins survive");
        // views are rebuilt by the first post-takeover scheduling event
        r.dispatch(Request::Reallocate);
        let views = r.cell_views().expect("restored master is still sharded");
        assert_eq!(views.len(), 2);
    }

    #[test]
    fn snapshot_corruption_detected_anywhere() {
        let m = sample_master("corrupt");
        let bytes = snapshot_state(&m).to_bytes();
        for pos in [0, 9, 40, bytes.len() / 2, bytes.len() - 1] {
            let mut bad = bytes.clone();
            bad[pos] ^= 0xFF;
            assert!(
                MasterCheckpoint::from_bytes(&bad).is_err(),
                "corruption at {pos} undetected"
            );
        }
        assert!(MasterCheckpoint::from_bytes(&bytes[..bytes.len() / 3]).is_err());
    }

    #[test]
    fn restore_rebuilds_equivalent_state() {
        let m = sample_master("restore");
        let snap = snapshot_state(&m);
        let r = restore(&snap, m.store().clone()).unwrap();
        assert_eq!(r.state_view(None), m.state_view(None));
        assert_eq!(r.epoch(), m.epoch());
        for (a, b) in m.slaves.iter().zip(&r.slaves) {
            assert_eq!(a.inventory(), b.inventory(), "{} book differs", a.name);
            assert_eq!(a.used(), b.used());
        }
    }

    #[test]
    fn books_digest_mismatch_refused() {
        let m = sample_master("digest");
        let mut snap = snapshot_state(&m);
        snap.books_digest ^= 1;
        let err = restore(&snap, m.store().clone()).unwrap_err();
        assert!(err.to_string().contains("placement digest"), "{err:#}");
    }

    #[test]
    fn wal_records_roundtrip_and_torn_tail_truncates() {
        let s = store("wal");
        let mut log = HaLog::new(s.clone(), 1000, 3, 0);
        let reqs = [
            Request::AdvanceSteps { app: AppId(1), steps: 5 },
            Request::Reallocate,
            Request::Complete { app: AppId(2) },
        ];
        for r in &reqs {
            log.append(7, &wire::encode_request(r)).unwrap();
        }
        let recs = read_wal(&s).unwrap();
        assert_eq!(recs.len(), 3);
        assert_eq!(recs[0].epoch, 7);
        assert_eq!(recs.iter().map(|r| r.seq).collect::<Vec<_>>(), vec![1, 2, 3]);
        for (rec, req) in recs.iter().zip(&reqs) {
            assert_eq!(&wire::decode_request(&rec.bytes).unwrap(), req);
        }
        // tear the last record: earlier records still replay
        let path = s.wal_path();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 5]).unwrap();
        let recs = read_wal(&s).unwrap();
        assert_eq!(recs.len(), 2, "torn tail truncates, does not fail");
        // flip a byte inside record 1's body: replay stops before it
        let mut bad = bytes.clone();
        bad[WAL_HEADER + 2] ^= 0xFF;
        std::fs::write(&path, &bad).unwrap();
        assert!(read_wal(&s).unwrap().is_empty());
    }

    /// A takeover master must keep refusing double-applies for retry ids
    /// it already answered: the WAL carries each mutation's rid (v1.3) and
    /// replay repopulates the dedupe memory.
    #[test]
    fn wal_replay_rebuilds_retry_dedupe() {
        let s = store("dedupe_replay");
        let mut m = DormMaster::new(
            &ClusterConfig::uniform(3, Res::cpu_gpu_ram(12.0, 0.0, 64.0)),
            DormConfig { theta1: 0.5, theta2: 0.5 },
            s.clone(),
        )
        .with_ha(1000, 3, 0)
        .unwrap();
        let app = match m.dispatch_rid(Request::Submit { spec: spec(6) }, Some(99)) {
            crate::proto::Response::Submitted { app } => app,
            other => panic!("submit answered {other:?}"),
        };
        drop(m);
        let (mut r, _) = load_master(&s).unwrap().expect("journaled master reloads");
        assert_eq!(r.state_view(None).active_apps, 1);
        // the client re-dials the standby and re-sends the same frame
        assert_eq!(
            r.dispatch_rid(Request::Submit { spec: spec(6) }, Some(99)),
            crate::proto::Response::Submitted { app },
            "replayed WAL must remember rid 99"
        );
        assert_eq!(r.state_view(None).active_apps, 1, "retry double-applied after takeover");
    }
}
